//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Wall-clock measurement with warmup, fixed iteration budget and robust
//! summary statistics; every bench binary and the table/figure
//! reproduction harness is built on this. [`BenchReport`] adds the
//! machine-readable side: every bench binary appends its measurements to
//! a report and writes `BENCH_<name>.json` (or the `--json <path>`
//! override) so the perf trajectory is trackable across PRs.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::json::Json;

/// Summary of one benchmark: all times in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean ms per iteration.
    pub mean_ms: f64,
    /// Median ms per iteration.
    pub p50_ms: f64,
    /// 95th-percentile ms.
    pub p95_ms: f64,
    /// 99th-percentile ms.
    pub p99_ms: f64,
    /// Minimum ms.
    pub min_ms: f64,
}

impl BenchStats {
    /// Computes stats from raw per-iteration durations (ms).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Self {
            iters: n,
            mean_ms: samples.iter().sum::<f64>() / n as f64,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            min_ms: samples[0],
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  p50 {:.3}  p95 {:.3}  p99 {:.3}  (n={})",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.iters
        )
    }
}

/// Runs `f` with `warmup` unmeasured iterations, then measures until either
/// `max_iters` iterations or `budget_ms` of wall time (whichever first,
/// with at least one measured iteration).
pub fn bench_ms(warmup: usize, max_iters: usize, budget_ms: f64, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
        if start.elapsed().as_secs_f64() * 1e3 > budget_ms {
            break;
        }
    }
    BenchStats::from_samples(samples)
}

/// Prevents the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Machine-readable results for one bench binary.
///
/// Rows are `(label, ns/op, batch size, config)` plus free-form extra
/// fields; [`BenchReport::write`] emits
/// `{"bench": <name>, "meta": {...}, "results": [...]}` so cross-PR
/// tooling can diff the perf trajectory without scraping stdout. The
/// `meta` object captures the run environment — git sha, hardware
/// thread count — plus any caller-set keys ([`BenchReport::set_meta`],
/// e.g. the engine/planner config under measurement), so two reports
/// are comparable without reconstructing how they were produced.
/// [`BenchReport::validate`] is the schema contract both sides agree
/// on, pinned by the round-trip test below.
pub struct BenchReport {
    name: String,
    meta: Vec<(String, Json)>,
    entries: Vec<Json>,
}

/// The commit the binary was built from: `git rev-parse HEAD` in the
/// working directory at run time, `"unknown"` outside a git checkout.
fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

impl BenchReport {
    /// An empty report for bench binary `name`, with the run metadata
    /// (git sha, hardware thread count) captured immediately.
    pub fn new(name: &str) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self {
            name: name.to_string(),
            meta: vec![
                ("git_sha".to_string(), Json::Str(git_sha())),
                ("threads".to_string(), Json::Num(threads as f64)),
            ],
            entries: Vec::new(),
        }
    }

    /// Sets (or replaces) one run-metadata key — e.g. the engine/planner
    /// configuration the whole report was measured under.
    pub fn set_meta(&mut self, key: &str, value: Json) {
        if let Some(slot) = self.meta.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.meta.push((key.to_string(), value));
        }
    }

    /// Records one measurement row.
    pub fn record(&mut self, label: &str, ns_per_op: f64, batch_size: usize, config: &str) {
        self.record_extra(label, ns_per_op, batch_size, config, Vec::new());
    }

    /// Records one measurement row with additional fields.
    pub fn record_extra(
        &mut self,
        label: &str,
        ns_per_op: f64,
        batch_size: usize,
        config: &str,
        extra: Vec<(&str, Json)>,
    ) {
        let mut fields = vec![
            ("label", Json::Str(label.to_string())),
            ("ns_per_op", Json::Num(ns_per_op)),
            ("batch_size", Json::Num(batch_size as f64)),
            ("config", Json::Str(config.to_string())),
        ];
        fields.extend(extra);
        self.entries.push(Json::obj(fields));
    }

    /// Resolves the output path: the value after a `--json` flag in
    /// `args`, or `BENCH_<name>.json` in the working directory. A
    /// `--json` value naming a directory — an existing one, or any path
    /// with a trailing `/` (created on the spot) — resolves to
    /// `<dir>/BENCH_<name>.json`: pass a directory when invoking
    /// `cargo bench` without `--bench` (cargo forwards the trailing args
    /// to *every* bench binary, and a single file path would make them
    /// overwrite each other).
    pub fn path_from_args(name: &str, args: &[String]) -> PathBuf {
        let default = PathBuf::from(format!("BENCH_{name}.json"));
        match args.iter().position(|a| a == "--json") {
            Some(i) => match args.get(i + 1) {
                Some(p) => {
                    if p.ends_with('/') || Path::new(p).is_dir() {
                        let dir = PathBuf::from(p);
                        std::fs::create_dir_all(&dir).ok();
                        dir.join(format!("BENCH_{name}.json"))
                    } else {
                        PathBuf::from(p)
                    }
                }
                None => {
                    eprintln!(
                        "[bench] --json given without a value; writing {}",
                        default.display()
                    );
                    default
                }
            },
            None => default,
        }
    }

    /// The shared tail of every bench binary: resolves the output path
    /// from `args` ([`BenchReport::path_from_args`]) and writes the
    /// report, logging — not panicking — on failure so a read-only
    /// working directory never kills a bench run.
    pub fn finish(&self, args: &[String]) {
        let path = Self::path_from_args(&self.name, args);
        if let Err(e) = self.write(&path) {
            eprintln!("failed to write {}: {e}", path.display());
        }
    }

    /// Writes the report; prints the destination so runs are greppable.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let payload = Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("meta", Json::Obj(self.meta.iter().cloned().collect())),
            ("results", Json::Arr(self.entries.clone())),
        ]);
        debug_assert!(
            Self::validate(&payload).is_ok(),
            "emitted report violates its own schema: {:?}",
            Self::validate(&payload)
        );
        std::fs::write(path, payload.to_string())?;
        eprintln!("[bench] wrote {} ({} rows)", path.display(), self.entries.len());
        Ok(())
    }

    /// Schema sanity for an emitted report: `bench` is a string, `meta`
    /// carries `git_sha` (string) and `threads` (number ≥ 1), and every
    /// `results` row has the four required fields with the right types.
    /// Consumers (cross-PR diff tooling) can call this before trusting a
    /// file; [`BenchReport::write`] checks it in debug builds.
    pub fn validate(report: &Json) -> Result<(), String> {
        report
            .get("bench")
            .and_then(|b| b.as_str())
            .ok_or("missing string field 'bench'")?;
        let meta = report.get("meta").ok_or("missing 'meta' object")?;
        meta.get("git_sha")
            .and_then(|s| s.as_str())
            .ok_or("meta missing string 'git_sha'")?;
        let threads = meta
            .get("threads")
            .and_then(|t| t.as_f64())
            .ok_or("meta missing numeric 'threads'")?;
        if threads < 1.0 {
            return Err(format!("meta.threads {threads} < 1"));
        }
        let rows = report
            .get("results")
            .and_then(|r| r.as_arr())
            .ok_or("missing array field 'results'")?;
        for (i, row) in rows.iter().enumerate() {
            row.get("label")
                .and_then(|v| v.as_str())
                .ok_or(format!("row {i}: missing string 'label'"))?;
            row.get("config")
                .and_then(|v| v.as_str())
                .ok_or(format!("row {i}: missing string 'config'"))?;
            for key in ["ns_per_op", "batch_size"] {
                row.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("row {i}: missing numeric '{key}'"))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = BenchStats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.p50_ms, 51.0);
        assert_eq!(s.p99_ms, 100.0);
        assert_eq!(s.min_ms, 1.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    #[test]
    fn report_round_trips_and_honours_json_flag() {
        let mut r = BenchReport::new("unit");
        r.set_meta("engine", Json::Str("mscm/auto".to_string()));
        r.record("row-a", 123.5, 32, "MSCM hash");
        r.record_extra("row-b", 7.0, 1, "baseline", vec![("shards", Json::Num(4.0))]);
        let dir = crate::util::temp_dir("bench-report");
        let path = dir.join("out.json");
        r.write(&path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit"));
        // Every emitted report satisfies the schema contract.
        BenchReport::validate(&parsed).unwrap();
        let meta = parsed.get("meta").unwrap();
        assert!(meta.get("git_sha").unwrap().as_str().is_some());
        assert!(meta.get("threads").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(meta.get("engine").unwrap().as_str(), Some("mscm/auto"));
        let rows = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("ns_per_op").unwrap().as_f64(), Some(123.5));
        assert_eq!(rows[1].get("shards").unwrap().as_f64(), Some(4.0));
        // Structural violations are rejected with a reason.
        assert!(BenchReport::validate(&Json::parse("{}").unwrap()).is_err());
        assert!(BenchReport::validate(
            &Json::parse(r#"{"bench":"x","meta":{"git_sha":"s","threads":4},"results":[{}]}"#)
                .unwrap()
        )
        .is_err());
        std::fs::remove_dir_all(dir).ok();

        let args = vec!["bin".to_string(), "--json".to_string(), "custom.json".to_string()];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            std::path::PathBuf::from("custom.json")
        );
        assert_eq!(
            BenchReport::path_from_args("unit", &["bin".to_string()]),
            std::path::PathBuf::from("BENCH_unit.json")
        );
        // a directory value scopes the file per bench (cargo forwards
        // trailing args to every bench binary)
        let dir = crate::util::temp_dir("bench-report-dir");
        let args = vec![
            "bin".to_string(),
            "--json".to_string(),
            dir.to_string_lossy().into_owned(),
        ];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            dir.join("BENCH_unit.json")
        );
        // a trailing slash marks a directory even before it exists,
        // and resolution creates it
        let sub = dir.join("sub");
        let args = vec![
            "bin".to_string(),
            "--json".to_string(),
            format!("{}/", sub.display()),
        ];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            sub.join("BENCH_unit.json")
        );
        assert!(sub.is_dir());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let mut n = 0u64;
        let s = bench_ms(2, 1_000_000, 20.0, || {
            n += 1;
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(s.iters >= 1);
        assert!(s.mean_ms > 0.0);
        assert!(s.iters < 1_000_000);
    }
}
