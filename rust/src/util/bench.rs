//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Wall-clock measurement with warmup, fixed iteration budget and robust
//! summary statistics; every bench binary and the table/figure
//! reproduction harness is built on this. [`BenchReport`] adds the
//! machine-readable side: every bench binary appends its measurements to
//! a report and writes `BENCH_<name>.json` (or the `--json <path>`
//! override) so the perf trajectory is trackable across PRs.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::json::Json;

/// Summary of one benchmark: all times in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean ms per iteration.
    pub mean_ms: f64,
    /// Median ms per iteration.
    pub p50_ms: f64,
    /// 95th-percentile ms.
    pub p95_ms: f64,
    /// 99th-percentile ms.
    pub p99_ms: f64,
    /// Minimum ms.
    pub min_ms: f64,
}

impl BenchStats {
    /// Computes stats from raw per-iteration durations (ms).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Self {
            iters: n,
            mean_ms: samples.iter().sum::<f64>() / n as f64,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            min_ms: samples[0],
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  p50 {:.3}  p95 {:.3}  p99 {:.3}  (n={})",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.iters
        )
    }
}

/// Runs `f` with `warmup` unmeasured iterations, then measures until either
/// `max_iters` iterations or `budget_ms` of wall time (whichever first,
/// with at least one measured iteration).
pub fn bench_ms(warmup: usize, max_iters: usize, budget_ms: f64, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
        if start.elapsed().as_secs_f64() * 1e3 > budget_ms {
            break;
        }
    }
    BenchStats::from_samples(samples)
}

/// Prevents the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Machine-readable results for one bench binary.
///
/// Rows are `(label, ns/op, batch size, config)` plus free-form extra
/// fields; [`BenchReport::write`] emits
/// `{"bench": <name>, "results": [...]}` so cross-PR tooling can diff
/// the perf trajectory without scraping stdout.
pub struct BenchReport {
    name: String,
    entries: Vec<Json>,
}

impl BenchReport {
    /// An empty report for bench binary `name`.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            entries: Vec::new(),
        }
    }

    /// Records one measurement row.
    pub fn record(&mut self, label: &str, ns_per_op: f64, batch_size: usize, config: &str) {
        self.record_extra(label, ns_per_op, batch_size, config, Vec::new());
    }

    /// Records one measurement row with additional fields.
    pub fn record_extra(
        &mut self,
        label: &str,
        ns_per_op: f64,
        batch_size: usize,
        config: &str,
        extra: Vec<(&str, Json)>,
    ) {
        let mut fields = vec![
            ("label", Json::Str(label.to_string())),
            ("ns_per_op", Json::Num(ns_per_op)),
            ("batch_size", Json::Num(batch_size as f64)),
            ("config", Json::Str(config.to_string())),
        ];
        fields.extend(extra);
        self.entries.push(Json::obj(fields));
    }

    /// Resolves the output path: the value after a `--json` flag in
    /// `args`, or `BENCH_<name>.json` in the working directory. A
    /// `--json` value naming a directory — an existing one, or any path
    /// with a trailing `/` (created on the spot) — resolves to
    /// `<dir>/BENCH_<name>.json`: pass a directory when invoking
    /// `cargo bench` without `--bench` (cargo forwards the trailing args
    /// to *every* bench binary, and a single file path would make them
    /// overwrite each other).
    pub fn path_from_args(name: &str, args: &[String]) -> PathBuf {
        let default = PathBuf::from(format!("BENCH_{name}.json"));
        match args.iter().position(|a| a == "--json") {
            Some(i) => match args.get(i + 1) {
                Some(p) => {
                    if p.ends_with('/') || Path::new(p).is_dir() {
                        let dir = PathBuf::from(p);
                        std::fs::create_dir_all(&dir).ok();
                        dir.join(format!("BENCH_{name}.json"))
                    } else {
                        PathBuf::from(p)
                    }
                }
                None => {
                    eprintln!(
                        "[bench] --json given without a value; writing {}",
                        default.display()
                    );
                    default
                }
            },
            None => default,
        }
    }

    /// The shared tail of every bench binary: resolves the output path
    /// from `args` ([`BenchReport::path_from_args`]) and writes the
    /// report, logging — not panicking — on failure so a read-only
    /// working directory never kills a bench run.
    pub fn finish(&self, args: &[String]) {
        let path = Self::path_from_args(&self.name, args);
        if let Err(e) = self.write(&path) {
            eprintln!("failed to write {}: {e}", path.display());
        }
    }

    /// Writes the report; prints the destination so runs are greppable.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let payload = Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("results", Json::Arr(self.entries.clone())),
        ]);
        std::fs::write(path, payload.to_string())?;
        eprintln!("[bench] wrote {} ({} rows)", path.display(), self.entries.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = BenchStats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.p50_ms, 51.0);
        assert_eq!(s.p99_ms, 100.0);
        assert_eq!(s.min_ms, 1.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    #[test]
    fn report_round_trips_and_honours_json_flag() {
        let mut r = BenchReport::new("unit");
        r.record("row-a", 123.5, 32, "MSCM hash");
        r.record_extra("row-b", 7.0, 1, "baseline", vec![("shards", Json::Num(4.0))]);
        let dir = crate::util::temp_dir("bench-report");
        let path = dir.join("out.json");
        r.write(&path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit"));
        let rows = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("ns_per_op").unwrap().as_f64(), Some(123.5));
        assert_eq!(rows[1].get("shards").unwrap().as_f64(), Some(4.0));
        std::fs::remove_dir_all(dir).ok();

        let args = vec!["bin".to_string(), "--json".to_string(), "custom.json".to_string()];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            std::path::PathBuf::from("custom.json")
        );
        assert_eq!(
            BenchReport::path_from_args("unit", &["bin".to_string()]),
            std::path::PathBuf::from("BENCH_unit.json")
        );
        // a directory value scopes the file per bench (cargo forwards
        // trailing args to every bench binary)
        let dir = crate::util::temp_dir("bench-report-dir");
        let args = vec![
            "bin".to_string(),
            "--json".to_string(),
            dir.to_string_lossy().into_owned(),
        ];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            dir.join("BENCH_unit.json")
        );
        // a trailing slash marks a directory even before it exists,
        // and resolution creates it
        let sub = dir.join("sub");
        let args = vec![
            "bin".to_string(),
            "--json".to_string(),
            format!("{}/", sub.display()),
        ];
        assert_eq!(
            BenchReport::path_from_args("unit", &args),
            sub.join("BENCH_unit.json")
        );
        assert!(sub.is_dir());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let mut n = 0u64;
        let s = bench_ms(2, 1_000_000, 20.0, || {
            n += 1;
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(s.iters >= 1);
        assert!(s.mean_ms > 0.0);
        assert!(s.iters < 1_000_000);
    }
}
