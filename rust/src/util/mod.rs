//! In-tree utility substrate.
//!
//! The build environment is offline with only the `xla` dependency closure
//! vendored, so the pieces a project would normally pull from crates.io —
//! a seedable RNG, a JSON emitter, a micro-bench harness, temp-dir
//! helpers — are implemented here.

pub mod bench;
pub mod json;
pub mod rng;

pub use bench::{bench_ms, BenchReport, BenchStats};
pub use json::Json;
pub use rng::Rng;

/// Creates a unique temporary directory (tests and artifacts).
pub fn temp_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("mscm-xmr-{tag}-{pid}-{n}"));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

#[cfg(test)]
mod tests {
    #[test]
    fn temp_dirs_are_unique() {
        let a = super::temp_dir("t");
        let b = super::temp_dir("t");
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        std::fs::remove_dir_all(a).ok();
        std::fs::remove_dir_all(b).ok();
    }
}
