//! A minimal JSON value + emitter (serde is unavailable offline).
//!
//! Used for benchmark reports and coordinator request/response payloads.
//! Parsing supports the subset the coordinator protocol needs: objects,
//! arrays, strings, numbers, booleans and null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object builder.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Accessor: object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Accessor: number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Accessor: string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Accessor: array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serializes to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parses a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: find the char boundary.
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end]).map_err(|e| e.to_string())?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let v = Json::obj(vec![
            ("name", Json::Str("wiki-500k".into())),
            ("nums", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : null } ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::Str("héllo →".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
