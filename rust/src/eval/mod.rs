//! Ranking-quality evaluation — the standard XMC metrics (precision@k,
//! recall@k, nDCG@k) used by the extreme-classification literature the
//! paper builds on. MSCM itself is accuracy-neutral (exactness claim),
//! so these metrics are how a deployment verifies that a *model* (or a
//! beam-width choice) is good, and how the beam-width/accuracy trade-off
//! of Alg. 1 is quantified.

use crate::inference::Prediction;

/// Accumulates ranking metrics over a test set.
#[derive(Clone, Debug, Default)]
pub struct RankingMetrics {
    /// Number of evaluated queries.
    pub queries: usize,
    /// Σ precision@k numerators per k (index 0 = @1).
    hits_at: Vec<f64>,
    /// Σ recall@k per k.
    recall_at: Vec<f64>,
    /// Σ nDCG@k per k.
    ndcg_at: Vec<f64>,
    /// Largest k tracked.
    pub max_k: usize,
}

impl RankingMetrics {
    /// Tracks metrics up to `max_k`.
    pub fn new(max_k: usize) -> Self {
        Self {
            queries: 0,
            hits_at: vec![0.0; max_k],
            recall_at: vec![0.0; max_k],
            ndcg_at: vec![0.0; max_k],
            max_k,
        }
    }

    /// Adds one query's ranked predictions against its true label set.
    /// `label_of` maps a predicted bottom-layer column to the original
    /// label id (identity for synthetic models, `TrainedModel::
    /// label_perm` for trained ones).
    pub fn add(&mut self, preds: &[Prediction], truth: &[u32], label_of: impl Fn(u32) -> u32) {
        if truth.is_empty() {
            return;
        }
        self.queries += 1;
        let mut hits = 0usize;
        let mut dcg = 0.0f64;
        // ideal DCG@k for |truth| relevant items
        let mut idcg = vec![0.0f64; self.max_k];
        let mut acc = 0.0;
        for i in 0..self.max_k {
            if i < truth.len() {
                acc += 1.0 / ((i + 2) as f64).log2();
            }
            idcg[i] = acc;
        }
        for k in 0..self.max_k {
            if let Some(p) = preds.get(k) {
                if truth.contains(&label_of(p.label)) {
                    hits += 1;
                    dcg += 1.0 / ((k + 2) as f64).log2();
                }
            }
            self.hits_at[k] += hits as f64 / (k + 1) as f64;
            self.recall_at[k] += hits as f64 / truth.len() as f64;
            self.ndcg_at[k] += if idcg[k] > 0.0 { dcg / idcg[k] } else { 0.0 };
        }
    }

    /// Precision@k (1-based k).
    pub fn precision_at(&self, k: usize) -> f64 {
        self.avg(&self.hits_at, k)
    }

    /// Recall@k (1-based k).
    pub fn recall_at(&self, k: usize) -> f64 {
        self.avg(&self.recall_at, k)
    }

    /// nDCG@k (1-based k).
    pub fn ndcg_at(&self, k: usize) -> f64 {
        self.avg(&self.ndcg_at, k)
    }

    fn avg(&self, v: &[f64], k: usize) -> f64 {
        assert!((1..=self.max_k).contains(&k), "k out of range");
        if self.queries == 0 {
            0.0
        } else {
            v[k - 1] / self.queries as f64
        }
    }

    /// One-line summary (`P@1/3/5` style, as XMC papers report).
    pub fn summary(&self) -> String {
        let ks: Vec<usize> = [1usize, 3, 5]
            .into_iter()
            .filter(|&k| k <= self.max_k)
            .collect();
        let fmt = |f: &dyn Fn(usize) -> f64| {
            ks.iter()
                .map(|&k| format!("{:.4}", f(k)))
                .collect::<Vec<_>>()
                .join("/")
        };
        format!(
            "n={} P@{}={} R@{}={} nDCG@{}={}",
            self.queries,
            ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("/"),
            fmt(&|k| self.precision_at(k)),
            ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("/"),
            fmt(&|k| self.recall_at(k)),
            ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("/"),
            fmt(&|k| self.ndcg_at(k)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn preds(labels: &[u32]) -> Vec<Prediction> {
        labels
            .iter()
            .enumerate()
            .map(|(i, &label)| Prediction {
                label,
                score: 1.0 - i as f32 * 0.1,
            })
            .collect()
    }

    #[test]
    fn perfect_ranking() {
        let mut m = RankingMetrics::new(5);
        m.add(&preds(&[7, 8, 9]), &[7, 8, 9], |l| l);
        assert_eq!(m.precision_at(1), 1.0);
        assert_eq!(m.precision_at(3), 1.0);
        assert!((m.precision_at(5) - 3.0 / 5.0).abs() < 1e-12);
        assert_eq!(m.recall_at(3), 1.0);
        assert_eq!(m.ndcg_at(3), 1.0);
    }

    #[test]
    fn miss_at_one_hit_at_two() {
        let mut m = RankingMetrics::new(3);
        m.add(&preds(&[5, 7]), &[7], |l| l);
        assert_eq!(m.precision_at(1), 0.0);
        assert_eq!(m.precision_at(2), 0.5);
        assert_eq!(m.recall_at(2), 1.0);
        // dcg = 1/log2(3), idcg = 1/log2(2) = 1
        assert!((m.ndcg_at(2) - 1.0 / 3f64.log2()).abs() < 1e-12);
    }

    #[test]
    fn label_mapping_applied() {
        let mut m = RankingMetrics::new(1);
        // predicted column 0 maps to original label 42
        m.add(&preds(&[0]), &[42], |_| 42);
        assert_eq!(m.precision_at(1), 1.0);
    }

    #[test]
    fn averages_over_queries() {
        let mut m = RankingMetrics::new(1);
        m.add(&preds(&[1]), &[1], |l| l);
        m.add(&preds(&[2]), &[3], |l| l);
        assert_eq!(m.precision_at(1), 0.5);
        assert_eq!(m.queries, 2);
    }

    #[test]
    fn empty_truth_skipped() {
        let mut m = RankingMetrics::new(3);
        m.add(&preds(&[1]), &[], |l| l);
        assert_eq!(m.queries, 0);
        assert_eq!(m.precision_at(3), 0.0);
    }
}
