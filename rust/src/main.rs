//! `repro` — the MSCM-XMR command-line launcher.
//!
//! Subcommands (run `repro help` for details):
//!
//! - model production: `synth-model`, `train`, `gen-data`, `stats`, `shard`
//! - inference: `infer`, `plan` (per-chunk kernel-plan inspection),
//!   `serve` (single engine, label-space sharded scatter-gather via
//!   `--shards N` / `--shards-dir dir/`, or cross-process via
//!   `--remote host:port,...`); `shard-host` (host one shard file over
//!   TCP for remote serving); `--iter auto` enables the cost-model
//!   kernel planner on any of them
//! - observability: `metrics` (poll a live shard host's stats over the
//!   wire `Stats` frame, once or as windowed diffs; `--traces` polls the
//!   host's tail-sampling flight recorder over the wire `Traces` frame
//!   instead); `infer --trace` (per-query layer traces + the plan-drift
//!   join); `serve --metrics-addr/--stats-interval/--trace-sample`
//!   (live exposition, periodic windowed stats, sampled request
//!   traces); `serve --flight-recorder N` sizes the coordinator-side
//!   flight recorder ring (0 disables tracing entirely)
//! - paper reproduction: `bench table|figure3|figure4|figure5|figure6|
//!   table4|table5|table6|all`
//! - runtime: `xla-smoke` (load + execute the AOT artifacts)
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`): the build
//! environment vendors only the `xla` dependency closure.

#![allow(clippy::too_many_arguments)]

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use mscm_xmr::coordinator::{Coordinator, CoordinatorConfig};
use mscm_xmr::data::corpus::{Corpus, CorpusSpec};
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::data::svmlight::{load_svmlight, save_svmlight, SvmlightData};
use mscm_xmr::data::synthetic::paper_suite;
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::repro;
use mscm_xmr::metrics::Snapshot;
use mscm_xmr::shard::{
    load_shard, load_shards, partition, partition_planned, poll_stats, poll_traces, save_shard_v4,
    save_shards, shard_file_name, FaultPlan,
    RemoteConfig, RemoteCoordinatorConfig, RemoteShardedCoordinator, ShardHost, ShardHostConfig,
    ShardedCoordinator, ShardedCoordinatorConfig, ShardedEngine,
};
use mscm_xmr::train::{train_model, RankerParams, Tfidf};
use mscm_xmr::tree::{load_model, save_model};
use mscm_xmr::util::Json;

const HELP: &str = "\
repro — MSCM for sparse XMR trees (WWW'22 reproduction)

USAGE: repro <command> [--key value ...]

MODEL PRODUCTION
  synth-model   --dataset <name>|--labels N --dim N [--branching B] [--out m.bin]
  gen-data      --out corpus.svm [--docs N] [--topics N] [--vocab N]
  train         --data corpus.svm [--branching B] [--out m.bin]
  stats         --model m.bin
  shard         --model m.bin --shards S --out dir/   (split into S shard files;
                cuts balanced by subtree nnz; with --iter auto [--calibrate N]
                [--approx] cuts balance planned resident bytes instead and
                each shard writes layout-resolved, mmap-servable (MSCMXMR4)
                with its kernel plan; MSCM_FORCE_MMAP=1 makes hosts load
                such files through a read-only memory map)

INFERENCE
  infer         --model m.bin --queries q.svm [--algo mscm|baseline]
                [--iter marching|binary|hash|dense|auto] [--beam 10] [--topk 10]
                [--trace out.json] (write per-query layer traces — beam
                width, candidates, blocks per kernel/storage, expand and
                select ns — and print the plan-drift join afterwards)
  plan          --model m.bin [--algo mscm|baseline] [--calibrate N]
                [--batch-hint N] [--plan-query-nnz N] [--no-layout]
                (resolve the per-chunk kernel plan; print the detected
                SIMD level, the scalar/SIMD cost constants — fitted when
                --calibrate N times both kernel tiers — the per-layer
                method histogram with its SIMD-vs-scalar split, the
                storage-layout and tier histograms, and the side-index +
                weight memory vs the fixed hash / all-CSC baselines)
  eval          --data corpus.svm [--branching B] [--beams 1,5,10,20]
                [--test-frac 0.2]  (train/test split; P@k/R@k/nDCG per beam)
  serve         --model m.bin [--workers N] [--max-batch N] [--rps N]
                [--requests N] (synthetic load; prints latency stats)
                [--iter ...|auto [--calibrate N]]
                [--shards S | --shards-dir dir/] [--shard-workers N]
                (scatter-gather serving over a label-space partition)
                [--remote host:port,host:port,...] (cross-process: drive
                shard hosts over TCP; replicas of the same shard are
                grouped automatically by the id each host reports;
                --no-speculate disables speculative expansion,
                --round-timeout-ms N sets the per-round failover timeout,
                0 = wait forever; --deadline-ms N caps a whole batch's
                retries/backoff, 0 = no budget; --hedge re-issues a round
                on the next replica once the first read exceeds the
                shard's observed p99; --allow-partial serves live shards
                when a shard's replicas are all down, flagging the
                response degraded instead of failing the batch)
                [--metrics-addr H:P] (TCP exposition: each connection
                gets one Prometheus-style snapshot, then close)
                [--stats-interval S] (one-line windowed stats every S
                seconds) [--trace-sample N [--trace out.json]] (sample
                every Nth request into a trace file; the final metrics
                snapshot is appended)
                [--flight-recorder N] (size of the tail-sampling trace
                ring on the sharded/remote stacks — traces over the live
                p99 are pinned, the rest 1-in-8 sampled; default 256,
                0 disables tracing entirely; pinned tail traces are
                printed after the load loop)
  shard-host    --shard shard-000-of-004.bin [--addr 127.0.0.1:0]
                [--algo ...] [--iter ...|auto [--calibrate N]]
                [--no-speculate] [--no-metrics]  (host one shard over TCP
                for serve --remote; port 0 picks a free port and prints
                it; answers the wire Stats poll unless --no-metrics)
                [--flight-recorder N] (host-side tail-sampling trace
                ring, answering the wire Traces poll; default 256, 0
                disables the recorder and all per-round timing)
                chaos flags (deterministic fault injection, for drills —
                see shard::fault): [--fault-seed N] [--fault-refuse P]
                [--fault-drop-after N] [--fault-delay-ms N]
                [--fault-corrupt P] [--fault-truncate P]
                [--fault-stutter-ms N]  (P = per-connection probability
                in [0,1]; any flag arms the injector)
  metrics       --addr host:port [--format text|prom|json]
                [--interval S [--count N]]  (poll a live shard host's
                stats over the wire Stats frame; with --interval, print
                windowed diffs of successive snapshots — N windows then
                exit, 0 = forever)
                [--traces]  (poll the host's flight recorder over the
                wire Traces frame instead: one summary line per retained
                trace — newest first, pinned tail traces marked — or the
                full span trees with --format json; with --interval,
                re-poll every S seconds)

  --iter auto resolves a per-chunk kernel plan (cost model over chunk
  stats; --calibrate N times the kernels on N synthetic queries first)
  that also picks each chunk's weight storage layout (CSC, dense-rows,
  merged; --no-layout keeps the seed CSC layout everywhere) and kernel
  tier (scalar or runtime-dispatched SIMD — AVX2/NEON — where the cost
  model says the lanes amortize; MSCM_FORCE_SCALAR=1 forces scalar);
  predictions are bitwise identical to every fixed method. --approx
  additionally opts the planner into the lossy quantized weight layouts
  (f16, int8 with a per-chunk scale) on CSC-shaped chunks — smaller
  resident bytes, approximate scores; without --approx every layout
  stays exact.

PAPER REPRODUCTION (synthetic suite; see DESIGN.md §5-6)
  bench table    --branching 2|8|32 [--scale 10] [--only d1,d2] [--json f]
  bench figure3 | bench figure4   (speedups; same grid as tables)
  bench figure5  (vs NapkinXC reimplementation)
  bench figure6  [--threads 1,2,4,8]
  bench table4   [--labels 1000000] [--dim 400000] [--queries 256]
  bench table5 | bench table6
  bench all      [--json-dir reports/]

RUNTIME
  xla-smoke     [--artifacts artifacts/]

Common: --seed N, --queries N (batch count), --online N
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprint!("{HELP}");
        return ExitCode::FAILURE;
    }
    let cmd = args[0].clone();
    // `help` tolerates trailing words (`repro help serve`) and must not
    // trip the strict flag parser.
    if matches!(cmd.as_str(), "help" | "--help" | "-h") {
        print!("{HELP}");
        return ExitCode::SUCCESS;
    }
    let (sub, rest) = if cmd == "bench" {
        if args.len() < 2 {
            return usage_exit("bench needs a target (table|figure3|...|all)");
        }
        (Some(args[1].clone()), &args[2..])
    } else {
        (None, &args[1..])
    };
    let opts = match parse_kv(rest) {
        Ok(o) => o,
        Err(e) => return usage_exit(&e),
    };
    let r = match (cmd.as_str(), sub.as_deref()) {
        ("synth-model", _) => cmd_synth_model(&opts),
        ("gen-data", _) => cmd_gen_data(&opts),
        ("train", _) => cmd_train(&opts),
        ("stats", _) => cmd_stats(&opts),
        ("shard", _) => cmd_shard(&opts),
        ("shard-host", _) => cmd_shard_host(&opts),
        ("plan", _) => cmd_plan(&opts),
        ("infer", _) => cmd_infer(&opts),
        ("metrics", _) => cmd_metrics(&opts),
        ("eval", _) => cmd_eval(&opts),
        ("serve", _) => cmd_serve(&opts),
        ("xla-smoke", _) => cmd_xla_smoke(&opts),
        ("bench", Some("table")) => cmd_bench_table(&opts),
        ("bench", Some("figure3")) => cmd_bench_fig34(&opts, false),
        ("bench", Some("figure4")) => cmd_bench_fig34(&opts, true),
        ("bench", Some("figure5")) => cmd_bench_fig5(&opts),
        ("bench", Some("figure6")) => cmd_bench_fig6(&opts),
        ("bench", Some("table4")) => cmd_bench_table4(&opts),
        ("bench", Some("table5")) => bench_options(&opts).map(|b| repro::table5(&b)),
        ("bench", Some("table6")) => bench_options(&opts).map(|b| repro::table6(&b)),
        ("bench", Some("all")) => cmd_bench_all(&opts),
        ("bench", Some(target)) => {
            return usage_exit(&format!("unknown bench target '{target}'"));
        }
        _ => {
            return usage_exit(&format!("unknown command '{cmd}'"));
        }
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            if let Some(u) = e.downcast_ref::<UsageError>() {
                return usage_exit(&u.0);
            }
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// A bad command line (unknown subcommand, malformed flag/value): these
/// print a one-line reason plus the help text and exit non-zero.
#[derive(Debug)]
struct UsageError(String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

fn usage(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg.into()))
}

fn usage_exit(reason: &str) -> ExitCode {
    eprintln!("error: {reason}\n");
    eprint!("{HELP}");
    ExitCode::FAILURE
}

type Opts = HashMap<String, String>;

/// Parses `--key value` / `--flag` pairs, rejecting stray positional
/// tokens (a typoed `-flag` or a value without its key would otherwise be
/// silently ignored).
fn parse_kv(args: &[String]) -> Result<Opts, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if key.is_empty() {
                return Err("empty flag '--'".to_string());
            }
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            return Err(format!("unexpected argument '{a}' (flags are --key [value])"));
        }
    }
    Ok(map)
}

fn get<T: std::str::FromStr>(opts: &Opts, key: &str, default: T) -> Result<T, anyhow::Error>
where
    T::Err: std::fmt::Debug,
{
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|e| usage(format!("bad --{key} '{v}': {e:?}"))),
    }
}

/// Parses a comma-separated `--key a,b,c` list.
fn get_list<T: std::str::FromStr>(
    opts: &Opts,
    key: &str,
    default: Vec<T>,
) -> Result<Vec<T>, anyhow::Error>
where
    T::Err: std::fmt::Debug,
{
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|e| usage(format!("bad --{key} entry '{s}': {e:?}")))
            })
            .collect(),
    }
}

fn bench_options(opts: &Opts) -> Result<repro::BenchOptions, anyhow::Error> {
    let mut b = repro::BenchOptions {
        batch_queries: get(opts, "queries", 512usize)?,
        online_queries: get(opts, "online", 128usize)?,
        beam: get(opts, "beam", 10usize)?,
        topk: get(opts, "topk", 10usize)?,
        scale: get(opts, "scale", 10usize)?,
        seed: get(opts, "seed", 2022u64)?,
        only: Vec::new(),
    };
    if let Some(only) = opts.get("only") {
        b.only = only.split(',').map(|s| s.trim().to_string()).collect();
    }
    Ok(b)
}

fn engine_config(opts: &Opts) -> Result<EngineConfig, anyhow::Error> {
    let algo: MatmulAlgo = opts
        .get("algo")
        .map(|s| s.parse())
        .transpose()
        .map_err(|e| usage(e))?
        .unwrap_or(MatmulAlgo::Mscm);
    let iter: IterationMethod = opts
        .get("iter")
        .map(|s| s.parse())
        .transpose()
        .map_err(|e| usage(e))?
        .unwrap_or(IterationMethod::Hash);
    Ok(EngineConfig::new(algo, iter))
}

/// Planner knobs shared by `infer`/`serve`/`shard`/`plan`: the
/// calibration budget and the workload hints the cost model plans for.
fn planner_config(opts: &Opts) -> Result<PlannerConfig, anyhow::Error> {
    let d = PlannerConfig::default();
    Ok(PlannerConfig {
        calibrate: get(opts, "calibrate", 0usize)?,
        batch_hint: get(opts, "batch-hint", d.batch_hint)?,
        query_nnz_hint: get(opts, "plan-query-nnz", d.query_nnz_hint)?,
        seed: get(opts, "seed", d.seed)?,
        // --no-layout pins every chunk to the seed CSC layout (plan
        // ablation; also what shared-model engines do implicitly).
        storage: !opts.contains_key("no-layout"),
        // --approx opts into the lossy f16/int8 weight layouts; exact
        // planning (the default) never selects them.
        approx: opts.contains_key("approx"),
    })
}

fn cmd_synth_model(opts: &Opts) -> Result<(), anyhow::Error> {
    let branching = get(opts, "branching", 32usize)?;
    let seed = get(opts, "seed", 2022u64)?;
    let model = if let Some(name) = opts.get("dataset") {
        let scale = get(opts, "scale", 10usize)?;
        let spec = paper_suite(scale)
            .into_iter()
            .find(|s| s.name == name.as_str())
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
        mscm_xmr::data::synthetic::synth_model(&spec, branching, seed)
    } else {
        let spec = EnterpriseSpec {
            num_labels: get(opts, "labels", 100_000usize)?,
            dim: get(opts, "dim", 100_000usize)?,
            branching,
            col_nnz: get(opts, "col-nnz", 24usize)?,
            query_nnz: get(opts, "query-nnz", 12usize)?,
            seed,
        };
        spec.build_model()
    };
    println!("model: {}", model.stats());
    let out = opts.get("out").cloned().unwrap_or("model.bin".into());
    save_model(&model, &out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_gen_data(opts: &Opts) -> Result<(), anyhow::Error> {
    let spec = CorpusSpec {
        vocab: get(opts, "vocab", 5_000usize)?,
        topics: get(opts, "topics", 64usize)?,
        docs: get(opts, "docs", 2_000usize)?,
        seed: get(opts, "seed", 42u64)?,
        ..Default::default()
    };
    let corpus = Corpus::generate(spec.clone());
    let tfidf = Tfidf::fit(&corpus.docs, spec.vocab);
    let features = tfidf.transform(&corpus.docs);
    let out = opts.get("out").cloned().unwrap_or("corpus.svm".into());
    save_svmlight(
        &SvmlightData {
            features,
            labels: corpus.labels,
            num_labels: spec.topics,
        },
        &out,
    )?;
    println!("wrote {out} ({} docs, {} topics)", spec.docs, spec.topics);
    Ok(())
}

fn cmd_train(opts: &Opts) -> Result<(), anyhow::Error> {
    let data_path = opts
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data required"))?;
    let data = load_svmlight(data_path)?;
    let branching = get(opts, "branching", 16usize)?;
    let trained = train_model(
        &data.features,
        &data.labels,
        data.num_labels,
        branching,
        &RankerParams::default(),
        get(opts, "seed", 7u64)?,
    );
    println!("trained: {}", trained.model.stats());
    let out = opts.get("out").cloned().unwrap_or("model.bin".into());
    save_model(&trained.model, &out)?;
    // save the permutation alongside
    let perm = Json::Arr(
        trained
            .label_perm
            .iter()
            .map(|&l| Json::Num(l as f64))
            .collect(),
    );
    std::fs::write(format!("{out}.labels.json"), perm.to_string())?;
    println!("saved {out} (+ .labels.json)");
    Ok(())
}

fn cmd_stats(opts: &Opts) -> Result<(), anyhow::Error> {
    let path = opts
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let model = load_model(path, false)?;
    println!("{}", model.stats());
    for (l, layer) in model.layers.iter().enumerate() {
        // Per-layer chunk structure — the planner's cost-model inputs.
        let nchunks = layer.chunked.num_chunks();
        let (mut rows, mut row_len) = (0.0f64, 0.0f64);
        for c in 0..nchunks {
            let s = layer.chunked.chunk_stats(c);
            rows += s.rows as f64;
            row_len += s.avg_row_len;
        }
        println!(
            "layer {l}: nodes={} chunks={} nnz={} avg_col_nnz={:.1} \
             avg_chunk_rows={:.1} avg_row_len={:.2}",
            layer.num_nodes(),
            nchunks,
            layer.csc.nnz(),
            layer.csc.avg_col_nnz(),
            rows / nchunks.max(1) as f64,
            row_len / nchunks.max(1) as f64
        );
    }
    Ok(())
}

/// Splits a model file into `--shards` standalone shard files under
/// `--out` (canonical `shard-XXX-of-YYY.bin` names, loadable by
/// `serve --shards-dir`). With `--iter auto` the cut is balanced by the
/// bytes each subtree keeps resident under a global kernel plan
/// (quantized layouts included under `--approx`), each shard re-plans
/// its own chunks, and the files are written in the layout-resolved
/// `MSCMXMR4` envelope a host can serve straight off a memory map.
fn cmd_shard(opts: &Opts) -> Result<(), anyhow::Error> {
    let path = opts
        .get("model")
        .ok_or_else(|| usage("shard requires --model"))?;
    let shards = get(opts, "shards", 4usize)?;
    if shards == 0 {
        return Err(usage("--shards must be >= 1"));
    }
    let out = opts.get("out").cloned().unwrap_or_else(|| "shards".into());
    let model = load_model(path, false)?;
    println!("model: {}", model.stats());
    let config = engine_config(opts)?;
    let planned = config.iter == IterationMethod::Auto;
    let mut parts = if planned {
        // Plan the *global* model once so the cut balances the bytes
        // the planned layouts actually keep resident, then re-plan per
        // shard below (plans are per-shard over the shard's own chunks).
        let pc = planner_config(opts)?;
        let global = KernelPlan::auto(&model, config.algo, &pc);
        partition_planned(&model, shards, &global)
    } else {
        partition(&model, shards)
    };
    if parts.len() != shards {
        eprintln!(
            "note: clamped to {} shards (the root has only that many children)",
            parts.len()
        );
    }
    // --iter auto: resolve (and optionally calibrate) each shard's
    // kernel plan now, so the shard files serve without re-planning.
    if planned {
        let pc = planner_config(opts)?;
        for p in &mut parts {
            p.plan_auto(config.algo, &pc);
            println!(
                "shard {} plan:\n{}",
                p.spec.shard_id,
                p.plan.as_ref().unwrap().1.summary()
            );
        }
    }
    let paths = if planned {
        // Planned shards ship layout-resolved (V4): quantization baked
        // into the arrays, mmap-servable without a rewrite.
        std::fs::create_dir_all(&out)?;
        let mut paths = Vec::with_capacity(parts.len());
        for p in &parts {
            let path = shard_file_name(&out, p.spec.shard_id, p.spec.num_shards);
            save_shard_v4(p, &path)?;
            paths.push(path);
        }
        paths
    } else {
        save_shards(&parts, &out)?
    };
    for (s, p) in parts.iter().zip(&paths) {
        println!(
            "shard {}/{}: root children [{}, {}), labels [{}, {}) -> {}",
            s.spec.shard_id,
            s.spec.num_shards,
            s.spec.root_lo,
            s.spec.root_hi,
            s.spec.label_offset,
            s.spec.label_offset + s.spec.num_labels,
            p.display()
        );
    }
    println!("wrote {} shard files to {out}", paths.len());
    Ok(())
}

/// Resolves and prints a model's per-chunk kernel plan: the detected
/// SIMD level, the scalar/SIMD cost constants (fitted when `--calibrate`
/// timed both tiers), the per-layer method histogram with its
/// SIMD-vs-scalar split, and the side-index memory the plan needs versus
/// the fixed `hash` configuration (the planner's measurable savings).
fn cmd_plan(opts: &Opts) -> Result<(), anyhow::Error> {
    let path = opts
        .get("model")
        .ok_or_else(|| usage("plan requires --model"))?;
    let model = load_model(path, false)?;
    println!("model: {}", model.stats());
    let config = engine_config(opts)?;
    let algo = config.algo;
    let pc = planner_config(opts)?;
    if pc.calibrate > 0 {
        eprintln!("calibrating cost model on {} synthetic queries ...", pc.calibrate);
    }
    let level = mscm_xmr::sparse::SimdLevel::detect();
    println!(
        "simd: {} (runtime-dispatched; MSCM_FORCE_SCALAR=1 forces scalar)",
        level.label()
    );
    let cost = mscm_xmr::inference::CostModel::default().calibrate(&model, &pc);
    let fmt_k = |k: &[f64; 4]| {
        format!(
            "marching={:.3} binary={:.3} hash={:.3} dense={:.3}",
            k[0], k[1], k[2], k[3]
        )
    };
    println!(
        "cost constants (ns/unit, {}):",
        if pc.calibrate > 0 { "fitted" } else { "analytical defaults" }
    );
    println!("  scalar: {}", fmt_k(&cost.k));
    println!(
        "  simd:   {} (+{:.0} ns setup per block)",
        fmt_k(&cost.k_simd),
        mscm_xmr::inference::plan::SIMD_SETUP_NS
    );
    let plan = KernelPlan::auto_with_cost(&model, algo, &cost, &pc);
    println!(
        "plan (algo {}, query-nnz hint {}, batch hint {}):",
        if algo == MatmulAlgo::Mscm { "mscm" } else { "baseline" },
        pc.query_nnz_hint,
        pc.batch_hint
    );
    println!("{}", plan.summary());
    // The fixed-hash baseline is priced analytically (U32Map sizing is
    // deterministic in the entry count) — no second model copy, no
    // full-size side index built just to print this line.
    let hash_b = mscm_xmr::inference::plan::fixed_hash_side_bytes(&model, algo);
    let csc_w: usize = model.layers.iter().map(|l| l.chunked.weight_bytes()).sum();
    let auto_engine = InferenceEngine::new_with_plan(
        model,
        EngineConfig::new(algo, IterationMethod::Auto),
        plan,
    );
    let auto_b = auto_engine.side_index_bytes();
    println!(
        "side indexes: auto {} KiB vs fixed hash {} KiB ({:.1}% saved)",
        auto_b / 1024,
        hash_b / 1024,
        100.0 * (1.0 - auto_b as f64 / hash_b.max(1) as f64)
    );
    let auto_w = auto_engine.weight_bytes();
    println!(
        "weights: planned layout {} KiB vs all-CSC {} KiB ({:+.1}%)",
        auto_w / 1024,
        csc_w / 1024,
        100.0 * (auto_w as f64 / csc_w.max(1) as f64 - 1.0)
    );
    Ok(())
}

fn cmd_infer(opts: &Opts) -> Result<(), anyhow::Error> {
    let model = load_model(
        opts.get("model")
            .ok_or_else(|| anyhow::anyhow!("--model required"))?,
        true,
    )?;
    let queries = load_svmlight(
        opts.get("queries")
            .ok_or_else(|| anyhow::anyhow!("--queries required"))?,
    )?;
    let config = engine_config(opts)?;
    let dim = model.dim;
    let pc = planner_config(opts)?;
    let trace_path = opts.get("trace").cloned();
    let engine = InferenceEngine::new_with_planner(model, config, &pc);
    // --trace also enables the engine telemetry so the run ends with a
    // plan-drift join (measured vs cost-model-predicted ns per class).
    let engine = if trace_path.is_some() {
        engine.with_metrics_costed(&mscm_xmr::inference::CostModel::default(), &pc)
    } else {
        engine
    };
    let beam = get(opts, "beam", 10usize)?;
    let topk = get(opts, "topk", 10usize)?;
    let mut ws = engine.workspace();
    let mut traces = Vec::new();
    for i in 0..queries.features.rows {
        let mut q = queries.features.row_owned(i);
        // drop features beyond the model's dimension
        let keep: Vec<(u32, f32)> = q
            .indices
            .iter()
            .zip(&q.values)
            .filter(|(&f, _)| (f as usize) < dim)
            .map(|(&f, &v)| (f, v))
            .collect();
        q = mscm_xmr::sparse::SparseVec::from_pairs(keep);
        let preds = if trace_path.is_some() {
            let (preds, trace) = engine.predict_traced(&q, beam, topk);
            traces.push(trace.to_json());
            preds
        } else {
            engine.predict_with(&q, beam, topk, &mut ws)
        };
        let formatted: Vec<String> = preds
            .iter()
            .map(|p| format!("{}:{:.4}", p.label, p.score))
            .collect();
        println!("query {i}: {}", formatted.join(" "));
    }
    if let Some(path) = trace_path {
        let n = traces.len();
        std::fs::write(&path, Json::Arr(traces).to_string())?;
        eprintln!("wrote {n} query traces to {path}");
        if let Some(m) = engine.metrics() {
            eprint!("{}", m.plan_drift().summary());
        }
    }
    Ok(())
}

/// Polls a live serving process (any `shard-host` answering the wire
/// `Stats` frame) and prints its metrics snapshot — once, or as windowed
/// diffs with `--interval`. With `--traces`, polls the host's flight
/// recorder over the wire `Traces` frame instead and prints the
/// retained trace records (newest first, pinned tail traces marked).
fn cmd_metrics(opts: &Opts) -> Result<(), anyhow::Error> {
    let addr = parse_remote_addrs(
        opts.get("addr")
            .ok_or_else(|| usage("metrics requires --addr host:port"))?,
    )?[0];
    let format = opts.get("format").cloned().unwrap_or_else(|| "text".into());
    if !matches!(format.as_str(), "text" | "prom" | "json") {
        return Err(usage(format!("bad --format '{format}' (text|prom|json)")));
    }
    let interval = get(opts, "interval", 0u64)?;
    let count = get(opts, "count", 0usize)?;
    let rc = RemoteConfig::default();
    if opts.contains_key("traces") {
        if format == "prom" {
            return Err(usage("--traces renders text or json, not prom"));
        }
        let mut windows = 0usize;
        loop {
            let records = poll_traces(addr, &rc)?;
            if format == "json" {
                let arr = Json::Arr(records.iter().map(|r| r.to_json()).collect());
                println!("{arr}");
            } else {
                println!("{} retained traces @ {addr}", records.len());
                for r in &records {
                    println!("  {}", r.summary());
                }
            }
            windows += 1;
            if interval == 0 || (count > 0 && windows >= count) {
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_secs(interval));
        }
    }
    let render = |snap: &Snapshot| match format.as_str() {
        "prom" => snap.render_prometheus(),
        "json" => format!("{}\n", snap.to_json()),
        _ => snap.render_text(),
    };
    let mut last = poll_stats(addr, &rc)?;
    if interval == 0 {
        print!("{}", render(&last));
        return Ok(());
    }
    let mut windows = 0usize;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(interval));
        let snap = poll_stats(addr, &rc)?;
        println!("--- window {interval}s @ {addr} ---");
        print!("{}", render(&snap.diff(&last)));
        last = snap;
        windows += 1;
        if count > 0 && windows >= count {
            return Ok(());
        }
    }
}

/// Train/test split evaluation: quantifies the beam-width ↔ accuracy
/// trade-off of Alg. 1 (MSCM itself is accuracy-neutral — exactness).
fn cmd_eval(opts: &Opts) -> Result<(), anyhow::Error> {
    let data = load_svmlight(
        opts.get("data")
            .ok_or_else(|| anyhow::anyhow!("--data required"))?,
    )?;
    let test_frac: f64 = get(opts, "test-frac", 0.2f64)?;
    let n = data.features.rows;
    let n_test = ((n as f64 * test_frac) as usize).clamp(1, n - 1);
    let n_train = n - n_test;
    let train_idx: Vec<usize> = (0..n_train).collect();
    let xtrain = data.features.select_rows(&train_idx);
    let trained = train_model(
        &xtrain,
        &data.labels[..n_train],
        data.num_labels,
        get(opts, "branching", 16usize)?,
        &RankerParams::default(),
        get(opts, "seed", 7u64)?,
    );
    println!("trained on {n_train} rows: {}", trained.model.stats());
    let beams: Vec<usize> = get_list(opts, "beams", vec![1, 5, 10, 20])?;
    let engine = InferenceEngine::new(
        trained.model.clone(),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );
    let mut ws = engine.workspace();
    for beam in beams {
        let mut metrics = mscm_xmr::eval::RankingMetrics::new(5);
        for i in n_train..n {
            let preds = engine.predict_with(&data.features.row_owned(i), beam, 5, &mut ws);
            metrics.add(&preds, &data.labels[i], |c| {
                trained.label_perm[c as usize]
            });
        }
        println!("beam {beam:<4} {}", metrics.summary());
    }
    Ok(())
}

/// The three serving stacks behind `serve`, driven by one load loop.
enum Serving {
    Single(Coordinator),
    Sharded(ShardedCoordinator),
    Remote(RemoteShardedCoordinator),
}

impl Serving {
    fn submit(
        &self,
        q: mscm_xmr::sparse::SparseVec,
    ) -> Result<
        (u64, std::sync::mpsc::Receiver<mscm_xmr::coordinator::Response>),
        mscm_xmr::coordinator::SubmitError,
    > {
        match self {
            Serving::Single(c) => c.submit(q),
            Serving::Sharded(c) => c.submit(q),
            Serving::Remote(c) => c.submit(q),
        }
    }

    fn stats(&self) -> &mscm_xmr::coordinator::CoordinatorStats {
        match self {
            Serving::Single(c) => c.stats(),
            Serving::Sharded(c) => c.stats(),
            Serving::Remote(c) => c.stats(),
        }
    }

    /// Full metrics snapshot — front-door stats plus engine telemetry
    /// (and scatter/transport telemetry on the sharded stacks) — feeding
    /// `--metrics-addr` exposition and `--stats-interval` diffs.
    fn snapshot(&self) -> Snapshot {
        match self {
            Serving::Single(c) => c.snapshot(),
            Serving::Sharded(c) => c.snapshot(),
            Serving::Remote(c) => c.snapshot(),
        }
    }

    /// Per-shard scatter-round telemetry + transport counters, printed
    /// after the load loop.
    fn print_round_telemetry(&self) {
        match self {
            Serving::Single(_) => {}
            Serving::Sharded(c) => {
                if let Some(sc) = &c.stats().scatter {
                    println!("scatter rounds:\n{}", sc.summary());
                }
            }
            Serving::Remote(c) => {
                let rs = c.remote_stats();
                println!("transport: {}", rs.summary());
                println!("scatter rounds:\n{}", rs.scatter.summary());
            }
        }
    }

    /// Flight-recorder status plus the pinned tail traces, printed after
    /// the load loop (the single-engine stack has no scatter rounds to
    /// trace, so it carries no recorder).
    fn print_flight_recorder(&self) {
        let rec = match self {
            Serving::Single(_) => None,
            Serving::Sharded(c) => c.flight_recorder(),
            Serving::Remote(c) => c.flight_recorder(),
        };
        if let Some(rec) = rec {
            println!("{}", rec.status_line());
            for r in rec.export().iter().filter(|r| r.pinned).take(8) {
                println!("  {}", r.summary());
            }
        }
    }

    fn shutdown(self) {
        match self {
            Serving::Single(c) => c.shutdown(),
            Serving::Sharded(c) => c.shutdown(),
            Serving::Remote(c) => c.shutdown(),
        }
    }
}

/// Parses a comma-separated `host:port` list into socket addresses.
fn parse_remote_addrs(list: &str) -> Result<Vec<std::net::SocketAddr>, anyhow::Error> {
    use std::net::ToSocketAddrs;
    let mut addrs = Vec::new();
    for part in list.split(',') {
        let part = part.trim();
        let mut it = part
            .to_socket_addrs()
            .map_err(|e| usage(format!("bad --remote address '{part}': {e}")))?;
        addrs.push(
            it.next()
                .ok_or_else(|| usage(format!("--remote address '{part}' resolved to nothing")))?,
        );
    }
    if addrs.is_empty() {
        return Err(usage("--remote needs at least one host:port"));
    }
    Ok(addrs)
}

/// Hosts one shard file over TCP (the server half of `serve --remote`).
/// Runs until killed; `--addr` port 0 asks the OS for a free port, which
/// is printed once listening.
fn cmd_shard_host(opts: &Opts) -> Result<(), anyhow::Error> {
    let path = opts
        .get("shard")
        .ok_or_else(|| usage("shard-host requires --shard <shard file>"))?;
    let addr = opts.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:0".into());
    let shard = load_shard(path, false)?;
    let spec = shard.spec;
    let config = ShardHostConfig {
        engine: engine_config(opts)?,
        planner: planner_config(opts)?,
        speculate: !opts.contains_key("no-speculate"),
        metrics: !opts.contains_key("no-metrics"),
        flight_recorder: get(opts, "flight-recorder", 256usize)?,
    };
    // Any --fault-* flag arms the deterministic injector (chaos drills).
    let fault_keys = [
        "fault-seed",
        "fault-refuse",
        "fault-drop-after",
        "fault-delay-ms",
        "fault-corrupt",
        "fault-truncate",
        "fault-stutter-ms",
    ];
    let host = if fault_keys.iter().any(|k| opts.contains_key(*k)) {
        let mut plan = FaultPlan {
            seed: get(opts, "fault-seed", FaultPlan::default().seed)?,
            refuse_connect: get(opts, "fault-refuse", 0.0f64)?,
            delay_replies: std::time::Duration::from_millis(get(opts, "fault-delay-ms", 0u64)?),
            corrupt_frame: get(opts, "fault-corrupt", 0.0f64)?,
            truncate_frame: get(opts, "fault-truncate", 0.0f64)?,
            ..Default::default()
        };
        if opts.contains_key("fault-drop-after") {
            plan.drop_after_frames = Some(get(opts, "fault-drop-after", 0u32)?);
        }
        let stutter = get(opts, "fault-stutter-ms", 0u64)?;
        if stutter > 0 {
            plan.stutter = Some(std::time::Duration::from_millis(stutter));
        }
        eprintln!("fault injection armed: {plan:?}");
        ShardHost::with_faults(shard, config, addr.as_str(), plan)?
    } else {
        ShardHost::spawn(shard, config, addr.as_str())?
    };
    println!(
        "shard {}/{} (labels [{}, {})) listening on {}",
        spec.shard_id,
        spec.num_shards,
        spec.label_offset,
        spec.label_offset + spec.num_labels,
        host.local_addr()
    );
    host.wait();
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<(), anyhow::Error> {
    let config = engine_config(opts)?;
    let base = CoordinatorConfig {
        workers: get(opts, "workers", 4usize)?,
        max_batch: get(opts, "max-batch", 64usize)?,
        beam: get(opts, "beam", 10usize)?,
        topk: get(opts, "topk", 10usize)?,
        ..Default::default()
    };
    let num_shards = get(opts, "shards", 0usize)?;
    let shards_dir = opts.get("shards-dir");
    let remote = opts.get("remote");
    if num_shards > 0 && shards_dir.is_some() {
        return Err(usage("--shards and --shards-dir are mutually exclusive"));
    }
    if shards_dir.is_some() && opts.contains_key("model") {
        return Err(usage(
            "--model and --shards-dir are mutually exclusive (the shard files are the model)",
        ));
    }
    if remote.is_some() && (num_shards > 0 || shards_dir.is_some() || opts.contains_key("model")) {
        return Err(usage(
            "--remote is mutually exclusive with --model/--shards/--shards-dir \
             (the shard hosts own the model)",
        ));
    }

    let pc = planner_config(opts)?;
    // Any observability flag turns on the in-process engine telemetry
    // (remote shard hosts record their own — see shard-host --no-metrics).
    let observe = opts.contains_key("metrics-addr")
        || opts.contains_key("stats-interval")
        || opts.contains_key("trace-sample");
    // Cross-process serving: the model lives on the shard hosts; the
    // addresses are probed and grouped into replica sets by the shard id
    // each host reports.
    let (dim, coord) = if let Some(list) = remote {
        let addrs = parse_remote_addrs(list)?;
        let rc = RemoteConfig {
            speculate: !opts.contains_key("no-speculate"),
            round_timeout: std::time::Duration::from_millis(get(
                opts,
                "round-timeout-ms",
                5_000u64,
            )?),
            deadline: std::time::Duration::from_millis(get(opts, "deadline-ms", 0u64)?),
            hedge: opts.contains_key("hedge"),
            allow_partial: opts.contains_key("allow-partial"),
            flight_recorder: get(opts, "flight-recorder", 256usize)?,
            ..Default::default()
        };
        let coord = RemoteShardedCoordinator::start(
            &addrs,
            RemoteCoordinatorConfig { base, remote: rc },
        )?;
        eprintln!(
            "serving {} remote shards (L={}, d={}) via {} addresses",
            coord.num_shards(),
            coord.num_labels(),
            coord.dim(),
            addrs.len()
        );
        (coord.dim(), Serving::Remote(coord))
    } else if let Some(dir) = shards_dir {
        let shards = load_shards(dir, false)?;
        // Shards carrying stored plans serve them verbatim under
        // --iter auto; the rest plan themselves here.
        let engine = ShardedEngine::new_with_planner(shards, config, &pc);
        let engine = Arc::new(if observe { engine.with_metrics() } else { engine });
        eprintln!(
            "serving {} shards from {dir} (L={}, d={})",
            engine.num_shards(),
            engine.num_labels(),
            engine.dim()
        );
        if config.iter == IterationMethod::Auto {
            eprintln!(
                "planned side indexes: {} KiB across shards, weights {} KiB (stored layouts)",
                engine.side_index_bytes() / 1024,
                engine.weight_bytes() / 1024
            );
        }
        let dim = engine.dim();
        let coord = ShardedCoordinator::start(
            engine,
            ShardedCoordinatorConfig {
                base,
                shard_workers: get(opts, "shard-workers", 2usize)?,
                flight_recorder: get(opts, "flight-recorder", 256usize)?,
            },
        );
        (dim, Serving::Sharded(coord))
    } else {
        // Model: either from file or synthesized on the spot. Full-model
        // hash row maps only pay off unsharded — partition() slices raw
        // CSC and each shard engine builds its own side indices.
        let model = if let Some(path) = opts.get("model") {
            load_model(path, num_shards == 0)?
        } else {
            let spec = EnterpriseSpec {
                num_labels: get(opts, "labels", 100_000usize)?,
                dim: get(opts, "dim", 100_000usize)?,
                ..Default::default()
            };
            eprintln!(
                "no --model; synthesizing enterprise model (L={})",
                spec.num_labels
            );
            spec.build_model()
        };
        let dim = model.dim;
        if num_shards > 0 {
            let engine = ShardedEngine::from_model_with_planner(&model, num_shards, config, &pc);
            let engine = Arc::new(if observe { engine.with_metrics() } else { engine });
            eprintln!("partitioned into {} shards", engine.num_shards());
            if config.iter == IterationMethod::Auto {
                eprintln!(
                    "planned side indexes: {} KiB across shards, weights {} KiB (planned layouts)",
                    engine.side_index_bytes() / 1024,
                    engine.weight_bytes() / 1024
                );
            }
            let coord = ShardedCoordinator::start(
                engine,
                ShardedCoordinatorConfig {
                    base,
                    shard_workers: get(opts, "shard-workers", 2usize)?,
                    flight_recorder: get(opts, "flight-recorder", 256usize)?,
                },
            );
            (dim, Serving::Sharded(coord))
        } else {
            let engine = InferenceEngine::new_with_planner(model, config, &pc);
            let engine = Arc::new(if observe { engine.with_metrics() } else { engine });
            if config.iter == IterationMethod::Auto {
                eprintln!("kernel plan:\n{}", engine.plan().summary());
                eprintln!(
                    "planned side indexes: {} KiB, weights {} KiB (planned layouts)",
                    engine.side_index_bytes() / 1024,
                    engine.weight_bytes() / 1024
                );
            }
            (dim, Serving::Single(Coordinator::start(engine, base)))
        }
    };
    // Synthetic load: open-loop arrivals at --rps for --requests queries.
    let requests = get(opts, "requests", 2_000usize)?;
    let rps = get(opts, "rps", 2_000u64)?;
    let spec = mscm_xmr::data::synthetic::DatasetSpec {
        name: "serve-load",
        dim,
        num_labels: 1,
        paper_dim: dim,
        paper_labels: 1,
        query_nnz: get(opts, "query-nnz", 12usize)?,
        col_nnz: 1,
        sibling_overlap: 0.5,
        zipf_theta: 1.05,
    };
    let x = mscm_xmr::data::synthetic::synth_queries(&spec, requests, get(opts, "seed", 1u64)?);
    // --metrics-addr: an accept thread hands connections to this load
    // loop (which owns `coord`); each connection gets one Prometheus
    // snapshot and is closed — pollable with nc/curl between requests.
    let metrics_rx = match opts.get("metrics-addr") {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr.as_str())
                .map_err(|e| anyhow::anyhow!("--metrics-addr {addr}: {e}"))?;
            eprintln!("metrics exposition on {}", listener.local_addr()?);
            let (tx, rx) = std::sync::mpsc::channel::<std::net::TcpStream>();
            std::thread::Builder::new()
                .name("mscm-metrics-accept".into())
                .spawn(move || {
                    for conn in listener.incoming().flatten() {
                        if tx.send(conn).is_err() {
                            break;
                        }
                    }
                })?;
            Some(rx)
        }
        None => None,
    };
    let stats_every = get(opts, "stats-interval", 0u64)?;
    let trace_sample = get(opts, "trace-sample", 0usize)?;
    eprintln!("serving {requests} requests at {rps} rps ...");
    let interval = std::time::Duration::from_nanos(1_000_000_000 / rps.max(1));
    let mut rxs = Vec::with_capacity(requests);
    let t0 = std::time::Instant::now();
    let mut last_stats = (t0, coord.snapshot());
    for i in 0..requests {
        let target = t0 + interval * i as u32;
        if let Some(sleep) = target.checked_duration_since(std::time::Instant::now()) {
            std::thread::sleep(sleep);
        }
        match coord.submit(x.row_owned(i)) {
            Ok((_, rx)) => rxs.push((i, rx)),
            Err(e) => eprintln!("request {i}: {e}"),
        }
        if let Some(mrx) = &metrics_rx {
            while let Ok(mut conn) = mrx.try_recv() {
                use std::io::Write as _;
                let _ = conn.write_all(coord.snapshot().render_prometheus().as_bytes());
            }
        }
        if stats_every > 0 && last_stats.0.elapsed().as_secs() >= stats_every {
            let snap = coord.snapshot();
            let w = snap.diff(&last_stats.1);
            eprintln!(
                "[stats {}s] completed={} shed={} latency {}",
                stats_every,
                w.counters.get("coordinator.completed").copied().unwrap_or(0),
                w.counters.get("coordinator.shed").copied().unwrap_or(0),
                w.histograms
                    .get("coordinator.latency")
                    .map(|h| h.summary())
                    .unwrap_or_default()
            );
            last_stats = (std::time::Instant::now(), snap);
        }
    }
    let mut sampled = Vec::new();
    for (i, rx) in rxs {
        if let Ok(resp) = rx.recv() {
            if trace_sample > 0 && i % trace_sample == 0 {
                sampled.push(Json::obj(vec![
                    ("request", Json::Num(i as f64)),
                    ("queue_us", Json::Num(resp.queue_time.as_micros() as f64)),
                    ("total_us", Json::Num(resp.total_time.as_micros() as f64)),
                    ("batch_size", Json::Num(resp.batch_size as f64)),
                ]));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.stats();
    println!(
        "served {} ok / {} shed in {:.2}s ({:.0} qps)",
        stats.completed.load(std::sync::atomic::Ordering::Relaxed),
        stats.shed.load(std::sync::atomic::Ordering::Relaxed),
        wall,
        stats.completed.load(std::sync::atomic::Ordering::Relaxed) as f64 / wall
    );
    println!("latency: {}", stats.latency.summary());
    println!("queue:   {}", stats.queue_wait.summary());
    println!("mean batch: {:.1}", stats.mean_batch());
    coord.print_round_telemetry();
    coord.print_flight_recorder();
    if trace_sample > 0 {
        let out = opts.get("trace").cloned().unwrap_or_else(|| "traces.json".into());
        let n = sampled.len();
        let doc = Json::obj(vec![
            ("sample_every", Json::Num(trace_sample as f64)),
            ("sampled", Json::Arr(sampled)),
            ("snapshot", coord.snapshot().to_json()),
        ]);
        std::fs::write(&out, doc.to_string())?;
        println!("wrote {n} sampled traces (+ final snapshot) to {out}");
    }
    coord.shutdown();
    Ok(())
}

fn cmd_xla_smoke(opts: &Opts) -> Result<(), anyhow::Error> {
    let dir = opts.get("artifacts").cloned().unwrap_or("artifacts".into());
    let rt = mscm_xmr::runtime::XlaRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    for name in ["matmul_only", "layer_step", "full_inference"] {
        let path = format!("{dir}/{name}.hlo.txt");
        let comp = rt.load_hlo_text(&path)?;
        println!("loaded + compiled {}", comp.source);
    }
    println!("xla-smoke OK");
    Ok(())
}

fn cmd_bench_table(opts: &Opts) -> Result<(), anyhow::Error> {
    let branching = get(opts, "branching", 8usize)?;
    let b = bench_options(opts)?;
    let rows = repro::bench_table(branching, &b);
    repro::print_table(branching, &rows);
    if let Some(path) = opts.get("json") {
        repro::write_report(path, repro::rows_to_json(branching, &rows))?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_bench_fig34(opts: &Opts, online: bool) -> Result<(), anyhow::Error> {
    let b = bench_options(opts)?;
    for branching in [2usize, 8, 32] {
        let rows = repro::bench_table(branching, &b);
        repro::print_figure34(branching, &rows, online);
    }
    Ok(())
}

fn cmd_bench_fig5(opts: &Opts) -> Result<(), anyhow::Error> {
    let b = bench_options(opts)?;
    let rows = repro::bench_figure5(&b);
    repro::print_figure5(&rows);
    if let Some(path) = opts.get("json") {
        repro::write_report(path, repro::figure5_to_json(&rows))?;
    }
    Ok(())
}

fn cmd_bench_fig6(opts: &Opts) -> Result<(), anyhow::Error> {
    let b = bench_options(opts)?;
    let threads: Vec<usize> = get_list(opts, "threads", vec![1, 2, 4, 8])?;
    let rows = repro::bench_figure6(&b, &threads);
    repro::print_figure6(&rows);
    if let Some(path) = opts.get("json") {
        repro::write_report(path, repro::figure6_to_json(&rows))?;
    }
    Ok(())
}

fn cmd_bench_table4(opts: &Opts) -> Result<(), anyhow::Error> {
    let spec = EnterpriseSpec {
        num_labels: get(opts, "labels", 1_000_000usize)?,
        dim: get(opts, "dim", 400_000usize)?,
        branching: get(opts, "branching", 32usize)?,
        col_nnz: get(opts, "col-nnz", 24usize)?,
        query_nnz: get(opts, "query-nnz", 12usize)?,
        seed: get(opts, "seed", 0xE17E_2021u64)?,
    };
    let mut b = bench_options(opts)?;
    b.online_queries = get(opts, "queries", 256usize)?;
    let rows = repro::bench_table4(&spec, &b);
    repro::print_table4(&spec, &rows);
    if let Some(path) = opts.get("json") {
        repro::write_report(path, repro::table4_to_json(&spec, &rows))?;
    }
    Ok(())
}

fn cmd_bench_all(opts: &Opts) -> Result<(), anyhow::Error> {
    let dir = opts
        .get("json-dir")
        .cloned()
        .unwrap_or_else(|| "reports".to_string());
    std::fs::create_dir_all(&dir)?;
    let b = bench_options(opts)?;
    repro::table5(&b);
    for branching in [2usize, 8, 32] {
        let rows = repro::bench_table(branching, &b);
        repro::print_table(branching, &rows);
        repro::print_figure34(branching, &rows, false);
        repro::print_figure34(branching, &rows, true);
        repro::write_report(
            &format!("{dir}/table_b{branching}.json"),
            repro::rows_to_json(branching, &rows),
        )?;
    }
    let f5 = repro::bench_figure5(&b);
    repro::print_figure5(&f5);
    repro::write_report(&format!("{dir}/figure5.json"), repro::figure5_to_json(&f5))?;
    let f6 = repro::bench_figure6(&b, &[1, 2, 4, 8]);
    repro::print_figure6(&f6);
    repro::write_report(&format!("{dir}/figure6.json"), repro::figure6_to_json(&f6))?;
    let spec = EnterpriseSpec {
        num_labels: get(opts, "labels", 1_000_000usize)?,
        dim: get(opts, "dim", 400_000usize)?,
        ..Default::default()
    };
    let t4 = repro::bench_table4(&spec, &b);
    repro::print_table4(&spec, &t4);
    repro::write_report(&format!("{dir}/table4.json"), repro::table4_to_json(&spec, &t4))?;
    repro::table6(&b);
    println!("\nall reports in {dir}/");
    Ok(())
}
