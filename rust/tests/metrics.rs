//! Integration tests for the serving observability layer: the engine
//! drift join measures real work without disturbing results, the wire
//! `Stats` frame round-trips and rejects every truncation prefix (like
//! the other frames in `tests/wire.rs`), and a **live** `ShardHost` on
//! loopback answers stats polls mid-traffic while its serving results
//! stay bitwise identical to the unsharded reference engine.

use std::io::{Cursor, Write};
use std::net::SocketAddr;
use std::time::Duration;

use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::metrics::{Registry, ScatterMetrics, Snapshot};
use mscm_xmr::shard::wire::{self, MsgType};
use mscm_xmr::shard::{
    partition, poll_stats, RemoteConfig, RemoteGather, ShardHost, ShardHostConfig,
};
use mscm_xmr::tree::XmrModel;

fn spec(dim: usize, labels: usize) -> DatasetSpec {
    DatasetSpec {
        name: "metrics-prop",
        dim,
        num_labels: labels,
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: 10,
        col_nnz: 6,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

/// One frame's bytes → (type, payload) through the real reader.
fn frame_payload(bytes: &[u8]) -> std::io::Result<(MsgType, Vec<u8>)> {
    let mut payload = Vec::new();
    let ty = wire::read_frame(&mut Cursor::new(bytes), &mut payload)?;
    Ok((ty, payload))
}

/// Spawns one loopback host per shard of an `s`-way partition with the
/// given host config; returns the hosts plus single-replica groups.
fn spawn_hosts(
    model: &XmrModel,
    s: usize,
    config: ShardHostConfig,
) -> (Vec<ShardHost>, Vec<Vec<SocketAddr>>) {
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(model, s) {
        let host =
            ShardHost::spawn(shard, config.clone(), "127.0.0.1:0").expect("spawn shard host");
        groups.push(vec![host.local_addr()]);
        hosts.push(host);
    }
    (hosts, groups)
}

/// The acceptance property for the drift join: a metered engine serves
/// bitwise-identical predictions, and after a live run the join carries
/// measured ns *and* cost-model-predicted ns for every touched class.
#[test]
fn drift_join_from_a_live_run_measures_and_predicts() {
    let sp = spec(96, 256);
    let model = synth_model(&sp, 4, 0xD81F7);
    let queries = synth_queries(&sp, 12, 0x5EED);
    for cfg in [
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Hash),
    ] {
        let plain = InferenceEngine::new(model.clone(), cfg);
        let metered = InferenceEngine::new(model.clone(), cfg).with_metrics();
        let mut ws = metered.workspace();
        for qi in 0..queries.rows {
            let q = queries.row_owned(qi);
            assert_eq!(
                metered.predict_with(&q, 8, 5, &mut ws),
                plain.predict(&q, 8, 5),
                "{} q={qi}: metrics changed results",
                cfg.label()
            );
        }
        let m = metered.metrics().expect("metrics attached");
        let drift = m.plan_drift();
        assert_eq!(drift.layers.len(), m.depth());
        assert!(drift.total_measured_ns() > 0, "no measured time recorded");
        assert!(drift.total_predicted_ns() > 0, "no predicted cost joined");
        assert!(drift.ratio() > 0.0);
        assert!(!drift.cells.is_empty() && drift.cells.iter().all(|c| c.blocks > 0));
        // Every layer actually expanded once per query.
        for l in &drift.layers {
            assert_eq!(l.calls, queries.rows as u64, "layer {}", l.layer);
        }
        let j = drift.to_json();
        assert_eq!(
            j.get("layers").unwrap().as_arr().unwrap().len(),
            drift.layers.len()
        );
        assert!(drift.summary().contains("plan drift"));
        // The raw accumulators export under a namespace prefix.
        let mut snap = Snapshot::default();
        m.export_into(&mut snap, "engine.");
        assert!(snap.counters.get("engine.layer0.ns").copied().unwrap_or(0) > 0);
        assert_eq!(
            snap.counters["engine.layer0.calls"],
            queries.rows as u64
        );
    }
}

#[test]
fn stats_frames_round_trip_and_reject_every_truncation() {
    // A representative snapshot: counters, a gauge, a direct histogram
    // and scatter telemetry bridged in under a prefix.
    let reg = Registry::new();
    reg.counter("host.expand_frames").add(42);
    reg.counter("remote.rounds").add(7);
    reg.gauge("coordinator.mean_batch").set(3.25);
    let h = reg.histogram("latency");
    h.record(Duration::from_micros(250));
    h.record(Duration::from_millis(3));
    let sc = ScatterMetrics::new(2);
    sc.record_round(0, Duration::from_micros(90));
    sc.record_round(1, Duration::from_micros(410));
    sc.record_join_wait(Duration::from_micros(320));
    let mut snap = reg.snapshot();
    sc.snapshot_into(&mut snap, "scatter");

    let mut buf = Vec::new();
    wire::encode_stats(&mut buf, &snap);
    let (ty, payload) = frame_payload(&buf).expect("valid frame");
    assert_eq!(ty, MsgType::Stats);
    let back = wire::decode_stats(&payload).expect("decode");
    assert_eq!(back, snap, "snapshot round trip");

    // Poll frames carry an empty payload by contract.
    let mut poll = Vec::new();
    wire::encode_stats_poll(&mut poll);
    let (ty, p) = frame_payload(&poll).unwrap();
    assert_eq!(ty, MsgType::Stats);
    assert!(p.is_empty());
    wire::decode_stats_poll(&p).expect("empty poll accepted");
    assert!(wire::decode_stats_poll(&payload).is_err());

    // Every strict prefix of the frame fails at the reader...
    for cut in 0..buf.len() {
        let err = frame_payload(&buf[..cut]).expect_err(&format!("prefix of {cut} bytes"));
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut {cut}");
    }
    // ...and every strict payload prefix fails structurally (clean
    // error, no panic, no partial acceptance).
    for cut in 0..payload.len() {
        let err = wire::decode_stats(&payload[..cut])
            .expect_err(&format!("payload prefix of {cut} bytes"));
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "cut {cut}");
    }
    // Trailing garbage after a well-formed payload is rejected too.
    let mut trailing = payload.clone();
    trailing.push(0);
    assert!(wire::decode_stats(&trailing).is_err());
}

/// The acceptance property for live export: a running `ShardHost` is
/// pollable over the `Stats` frame mid-traffic — on the same connection
/// the rounds ride on — and serving results stay bitwise identical to
/// the unsharded reference the whole time.
#[test]
fn live_host_answers_stats_polls_while_serving_bitwise_results() {
    let sp = spec(96, 256);
    let model = synth_model(&sp, 4, 0x11FE);
    let queries = synth_queries(&sp, 8, 0xBEEF);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let reference = InferenceEngine::new(model.clone(), cfg);
    let (hosts, groups) = spawn_hosts(
        &model,
        2,
        ShardHostConfig {
            engine: cfg,
            ..Default::default() // metrics on by default
        },
    );
    let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None)
        .expect("connect remote partition");
    let mut last_expands = 0u64;
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        let want = reference.predict(&q, 6, 5);
        let got = g.predict(&q, 6, 5).expect("remote predict");
        assert_eq!(got, want, "q={qi}: results diverged while polling");
        let snap = g.poll_shard_stats(0).expect("mid-traffic stats poll");
        let expands = snap.counters["host.expand_frames"];
        assert!(
            expands > last_expands,
            "q={qi}: expand counter did not advance ({expands} <= {last_expands})"
        );
        last_expands = expands;
        assert!(snap.counters["host.stats_polls"] >= 1);
        // The engine telemetry travels the wire under the engine. prefix.
        assert!(snap.counters.contains_key("engine.layer0.ns"));
        assert!(
            snap.counters
                .iter()
                .any(|(k, &v)| k.starts_with("engine.layer") && k.ends_with(".ns") && v > 0),
            "q={qi}: no layer recorded time on shard 0"
        );
    }
    // The one-call client path: fresh connection, handshake, poll.
    let snap = poll_stats(groups[1][0], &RemoteConfig::default()).expect("poll_stats");
    assert!(snap.counters.contains_key("host.connections"));
    assert!(snap.counters.keys().any(|k| k.starts_with("engine.layer")));
    for h in hosts {
        h.shutdown();
    }
}

/// `metrics: false` keeps a host pollable (operational counters only)
/// but exports no engine series — the opt-out leaves the hot path with
/// no telemetry attached at all.
#[test]
fn metrics_disabled_host_polls_without_engine_series() {
    let sp = spec(64, 81);
    let model = synth_model(&sp, 3, 0xB0B1);
    let (hosts, groups) = spawn_hosts(
        &model,
        1,
        ShardHostConfig {
            metrics: false,
            ..Default::default()
        },
    );
    let snap = poll_stats(groups[0][0], &RemoteConfig::default()).expect("poll");
    assert!(snap.counters.contains_key("host.connections"));
    assert!(
        snap.counters.keys().all(|k| !k.starts_with("engine.")),
        "engine series exported with metrics disabled"
    );
    for h in hosts {
        h.shutdown();
    }
}

/// A `Stats` frame with a non-empty payload is not a valid poll: the
/// host answers with a malformed-frame `Error` instead of guessing.
#[test]
fn malformed_stats_poll_answered_with_error_frame() {
    let sp = spec(64, 81);
    let model = synth_model(&sp, 3, 0xB0B2);
    let (hosts, groups) = spawn_hosts(&model, 1, ShardHostConfig::default());

    let mut stream = std::net::TcpStream::connect(groups[0][0]).unwrap();
    let mut r = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut buf = Vec::new();
    let mut payload = Vec::new();
    wire::encode_hello(&mut buf);
    stream.write_all(&buf).unwrap();
    assert_eq!(
        wire::read_frame(&mut r, &mut payload).unwrap(),
        MsgType::ShardInfo
    );
    // A full snapshot body where the empty poll belongs.
    wire::encode_stats(&mut buf, &Snapshot::default());
    stream.write_all(&buf).unwrap();
    assert_eq!(
        wire::read_frame(&mut r, &mut payload).unwrap(),
        MsgType::Error
    );
    let (code, msg) = wire::decode_error(&payload).unwrap();
    assert_eq!(code, wire::ERR_MALFORMED);
    assert!(msg.contains("empty"), "{msg}");
    for h in hosts {
        h.shutdown();
    }
}
