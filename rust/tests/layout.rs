//! Property tests for plan-driven weight storage: every
//! [`ChunkStorage`] layout — uniform or mixed per chunk — is **bitwise
//! identical** to the seed all-`Csc` path, for both masked-matmul
//! algorithms, every iteration method (`Auto` included), online and
//! batch, unsharded and sharded (S ∈ {1, 4}; the remote-loopback leg
//! lives in `tests/remote.rs`), over ≥ 16 seeds of the shared
//! `tests/common` model generator (`MSCM_TEST_SEED` replayable).
//!
//! Plus the memory claims: a dense-planned chunk stored `DenseRows`
//! carries strictly fewer weight bytes than its CSC equivalent, and
//! engines actually apply their plan's layouts.

mod common;

use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::shard::{partition, ShardedEngine};
use mscm_xmr::sparse::{ChunkStorage, CscMatrix, SparseVec};
use mscm_xmr::tree::{Layer, XmrModel};

/// Acceptance floor: the layout grid runs over at least this many seeds.
const SEEDS: u64 = 16;

/// The method axis of the grid: the four kernels plus the planner.
const METHODS: [IterationMethod; 5] = [
    IterationMethod::MarchingPointers,
    IterationMethod::BinarySearch,
    IterationMethod::Hash,
    IterationMethod::DenseLookup,
    IterationMethod::Auto,
];

fn reference(model: &XmrModel) -> InferenceEngine {
    InferenceEngine::new(
        model.clone(),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers),
    )
}

#[test]
fn every_layout_is_bitwise_identical_unsharded() {
    common::run_cases_capped(SEEDS, 120, |_, case| {
        let reference = reference(&case.model);
        let rows = case.query_rows();
        for algo in MatmulAlgo::ALL {
            for iter in METHODS {
                for storage in ChunkStorage::ALL {
                    let cfg = EngineConfig::new(algo, iter);
                    let plan = KernelPlan::resolve(&case.model, cfg, &PlannerConfig::default())
                        .with_uniform_storage(storage);
                    let engine =
                        InferenceEngine::new_with_plan(case.model.clone(), cfg, plan);
                    for beam in [1usize, 4] {
                        assert_eq!(
                            engine.predict_batch(&case.queries, beam, 5),
                            reference.predict_batch(&case.queries, beam, 5),
                            "batch {algo:?}/{iter:?}/{storage:?} beam={beam} ({})",
                            case.shape
                        );
                        let mut ws = engine.workspace();
                        for (qi, q) in rows.iter().enumerate() {
                            assert_eq!(
                                engine.predict_with(q, beam, 5, &mut ws),
                                &reference.predict(q, beam, 5)[..],
                                "online {algo:?}/{iter:?}/{storage:?} beam={beam} q={qi} ({})",
                                case.shape
                            );
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn random_mixed_layouts_stay_exact() {
    common::run_cases_capped(SEEDS, 120, |_, case| {
        let reference = reference(&case.model);
        let mut g = common::ModelGen::new(case.seed ^ 0xD00D_1A0);
        for algo in MatmulAlgo::ALL {
            // Random method AND random layout per chunk — the fully
            // mixed dispatch surface.
            let mut plan = KernelPlan::uniform(&case.model, IterationMethod::MarchingPointers);
            for l in &mut plan.layers {
                for m in &mut l.methods {
                    *m = IterationMethod::ALL[g.pick(0..4)];
                }
                for s in &mut l.storage {
                    *s = ChunkStorage::ALL[g.pick(0..3)];
                }
            }
            let cfg = EngineConfig::new(algo, IterationMethod::Auto);
            let engine = InferenceEngine::new_with_plan(case.model.clone(), cfg, plan);
            assert_eq!(
                engine.predict_batch(&case.queries, 4, 5),
                reference.predict_batch(&case.queries, 4, 5),
                "{algo:?} ({})",
                case.shape
            );
        }
    });
}

#[test]
fn sharded_layouts_are_bitwise_identical() {
    common::run_cases_capped(SEEDS, 100, |case_id, case| {
        let reference = reference(&case.model);
        let rows = case.query_rows();
        for algo in MatmulAlgo::ALL {
            for s_count in [1usize, 4] {
                for storage in ChunkStorage::ALL {
                    // One method per (case, storage) cell keeps the grid
                    // bounded while covering all methods across seeds.
                    let iter =
                        IterationMethod::ALL[(case_id as usize + storage.index()) % 4];
                    let mut shards = partition(&case.model, s_count);
                    for sh in &mut shards {
                        let plan = KernelPlan::uniform(&sh.model, iter)
                            .with_uniform_storage(storage);
                        sh.plan = Some((algo, plan));
                    }
                    let sharded = ShardedEngine::new(
                        shards,
                        EngineConfig::new(algo, IterationMethod::Auto),
                    );
                    let batch = sharded.predict_batch(&case.queries, 3, 5, false);
                    let want = reference.predict_batch(&case.queries, 3, 5);
                    assert_eq!(
                        batch,
                        want,
                        "batch {algo:?}/{iter:?}/{storage:?} S={s_count} ({})",
                        case.shape
                    );
                    for (qi, q) in rows.iter().enumerate() {
                        assert_eq!(
                            sharded.predict(q, 3, 5),
                            reference.predict(q, 3, 5),
                            "online {algo:?}/{iter:?}/{storage:?} S={s_count} q={qi} ({})",
                            case.shape
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn engines_apply_their_plans_layouts() {
    common::run_cases_capped(4, 120, |_, case| {
        let engine = InferenceEngine::new(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
        );
        let plan = engine.plan().clone();
        for (li, layer) in engine.model().layers.iter().enumerate() {
            for (c, chunk) in layer.chunked.chunks.iter().enumerate() {
                assert_eq!(
                    chunk.storage,
                    plan.layer_storage(li)[c],
                    "layer {li} chunk {c} ({})",
                    case.shape
                );
            }
        }
    });
}

/// The pinned memory claim: a dense-planned chunk stored `DenseRows` is
/// strictly below its CSC equivalent — no `row_indices`, no row map.
#[test]
fn dense_planned_chunk_weight_bytes_strictly_below_csc() {
    let dim = 64usize;
    // One chunk of 4 sibling columns touching every row: exactly the
    // shape the planner re-lays as DenseRows.
    let cols: Vec<SparseVec> = (0..4)
        .map(|j| {
            SparseVec::from_pairs(
                (0..dim)
                    .map(|r| (r as u32, (r + j + 1) as f32 * 0.01))
                    .collect(),
            )
        })
        .collect();
    let model = XmrModel::new(
        dim,
        vec![Layer::new(CscMatrix::from_cols(cols, dim), &[0, 4], true)],
    );
    // The cost model itself picks DenseRows for this chunk.
    let plan = KernelPlan::auto(&model, MatmulAlgo::Mscm, &PlannerConfig::default());
    assert_eq!(plan.layer_storage(0)[0], ChunkStorage::DenseRows);

    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup);
    let csc = InferenceEngine::new_with_plan(
        model.clone(),
        cfg,
        KernelPlan::uniform(&model, IterationMethod::DenseLookup),
    );
    let dense = InferenceEngine::new_with_plan(
        model.clone(),
        cfg,
        KernelPlan::uniform(&model, IterationMethod::DenseLookup)
            .with_uniform_storage(ChunkStorage::DenseRows),
    );
    assert!(
        dense.weight_bytes() < csc.weight_bytes(),
        "DenseRows {} must be strictly below CSC {}",
        dense.weight_bytes(),
        csc.weight_bytes()
    );
    // Per chunk, and the row-index structures are really gone.
    let dr_layer = &dense.model().layers[0].chunked;
    let csc_layer = &csc.model().layers[0].chunked;
    assert!(dr_layer.chunk_weight_bytes(0) < csc_layer.chunk_weight_bytes(0));
    assert!(dr_layer.chunks[0].row_indices.is_empty());
    assert!(dr_layer.chunks[0].row_map.is_none());
    // A fixed-hash engine on the same model additionally pays the row
    // map; the DenseRows engine pays no side index at all.
    assert_eq!(dense.side_index_bytes(), 0);
    // And the layouts agree on the answers.
    let q = SparseVec::from_pairs(vec![(0, 1.0), (13, -0.5), (63, 2.0)]);
    assert_eq!(dense.predict(&q, 4, 4), csc.predict(&q, 4, 4));
}
