//! Quantized + memory-mapped storage properties (the `--approx` /
//! MSCMXMR4 acceptance gates):
//!
//! - the hand-rolled f16 codec round-trips within half-precision error
//!   bounds over a seeded value sweep, signs and zeros preserved,
//! - chunk-level quantization (`F16`/`Int8`) leaves every structure
//!   array bitwise-intact and reconstructs values within the layout's
//!   analytic error bound (f16: relative 2^-10; int8: scale/2),
//! - the `--approx` planner gate: the default plan never emits a
//!   quantized layout; the approx plan does, and its top-k rankings
//!   stay above the precision@5 floor against the exact oracle,
//! - exact modes are **exact**: a V4 shard served from the heap and the
//!   same file served via mmap rank bitwise-identically to an engine
//!   built from the in-memory model,
//! - the mmap path is cheap: resident heap stays below the file's
//!   weight bytes (and below the heap-parsed footprint), and the warm
//!   serving loop on a mapped engine — quantized engines included —
//!   touches the allocator zero times.
//!
//! Everything runs inside ONE `#[test]` (the process-wide allocator
//! tallies must not see sibling test threads), seeded via
//! `rust/tests/common` (`MSCM_TEST_SEED` replayable).

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::repro::precision_overlap_at_k;
use mscm_xmr::shard::{load_shard, load_shard_mmap, partition, save_shard_v4, ShardedEngine};
use mscm_xmr::sparse::{f16_to_f32, f32_to_f16, ChunkStorage, ChunkedMatrix};
use mscm_xmr::util::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static LIVE: AtomicI64 = AtomicI64::new(0);

/// Counts allocator entries and tracks live bytes (frees subtracted) so
/// one shim serves both the steady-state-zero and the resident-bytes
/// assertions.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            LIVE.fetch_add(layout.size() as i64, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as i64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            LIVE.fetch_add(layout.size() as i64, Ordering::Relaxed);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            LIVE.fetch_add(new_size as i64 - layout.size() as i64, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn live() -> i64 {
    LIVE.load(Ordering::Relaxed)
}

/// Half-precision relative error bound (10 mantissa bits, rounded to
/// nearest — 2^-11 — doubled for slack) plus an absolute epsilon that
/// covers the subnormal range.
fn f16_close(orig: f32, got: f32) -> bool {
    (orig - got).abs() <= orig.abs() / 1024.0 + 1e-6
}

fn f16_codec_bounds() {
    let mut rng = Rng::seed_from_u64(common::base_seed() ^ 0xF16);
    for _ in 0..10_000 {
        let v = rng.gen_f32(-8.0, 8.0);
        let rt = f16_to_f32(f32_to_f16(v));
        assert!(f16_close(v, rt), "f16 round trip {v} -> {rt}");
        // The sign bit survives every codec path, underflow-to-zero
        // included (negative zero stays negative).
        assert_eq!(
            v.is_sign_negative(),
            rt.is_sign_negative(),
            "sign lost: {v} -> {rt}"
        );
    }
    assert_eq!(f16_to_f32(f32_to_f16(0.0)).to_bits(), 0.0f32.to_bits());
    assert_eq!(f16_to_f32(f32_to_f16(1.0)), 1.0);
    assert_eq!(f16_to_f32(f32_to_f16(-2.5)), -2.5);
}

fn chunk_quantization_bounds() {
    let mut g = common::ModelGen::new(common::base_seed() ^ 0x0_8B17);
    for case in 0..12 {
        let (csc, offsets) = g.matrix();
        let exact = ChunkedMatrix::from_csc(&csc, &offsets, false);
        for target in [ChunkStorage::F16, ChunkStorage::Int8] {
            let mut q = exact.clone();
            q.apply_layout(&vec![target; q.num_chunks()]);
            let mut deq = Vec::new();
            for c in 0..exact.num_chunks() {
                let e = &exact.chunks[c];
                let quant = &q.chunks[c];
                assert_eq!(quant.storage, target, "case {case} chunk {c}");
                // Structure is untouched; only the payload is packed.
                assert!(quant.row_indices == e.row_indices);
                assert!(quant.row_ptr == e.row_ptr);
                assert!(quant.col_idx == e.col_idx);
                assert!(quant.values.is_empty());
                if e.values.is_empty() {
                    continue;
                }
                quant.dequantize_into(&mut deq);
                assert_eq!(deq.len(), e.values.len(), "case {case} chunk {c}");
                for (i, (&orig, &got)) in e.values.iter().zip(&deq).enumerate() {
                    let ok = match target {
                        ChunkStorage::F16 => f16_close(orig, got),
                        _ => (orig - got).abs() <= quant.scale * 0.5 + 1e-4,
                    };
                    assert!(
                        ok,
                        "case {case} chunk {c} value {i}: {orig} -> {got} \
                         ({target:?}, scale {})",
                        quant.scale
                    );
                }
            }
        }
    }
}

/// The `--approx` gate: quantized layouts appear only when asked for,
/// and when they do, top-5 rankings stay above the precision floor and
/// warm quantized serving never touches the allocator.
fn approx_precision_gate() {
    let model = common::skewed_model(96, 400, 8, 0x51AB5);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let exact_plan = KernelPlan::auto(&model, MatmulAlgo::Mscm, &PlannerConfig::default());
    assert!(
        !exact_plan.uses_storage(ChunkStorage::F16)
            && !exact_plan.uses_storage(ChunkStorage::Int8),
        "quantized layouts must be opt-in"
    );
    let approx_plan = KernelPlan::auto(
        &model,
        MatmulAlgo::Mscm,
        &PlannerConfig {
            approx: true,
            ..PlannerConfig::default()
        },
    );
    assert!(
        approx_plan.uses_storage(ChunkStorage::F16)
            || approx_plan.uses_storage(ChunkStorage::Int8),
        "the approx plan quantized nothing — the gate below is vacuous"
    );
    let exact = InferenceEngine::new_with_plan(model.clone(), cfg, exact_plan);
    let quant = InferenceEngine::new_with_plan(model.clone(), cfg, approx_plan);
    let mut g = common::ModelGen::new(common::base_seed() ^ 0x9A7E);
    let queries = g.queries(model.dim, 64);
    let e = exact.predict_batch(&queries, 10, 10);
    let a = quant.predict_batch(&queries, 10, 10);
    let p5 = precision_overlap_at_k(&e, &a, 5);
    assert!(p5 >= 0.9, "precision@5 regression under --approx: {p5:.4}");

    // Warm, then pin: the dequant arena is workspace-resident, so the
    // second pass over the same queries must not allocate.
    let rows: Vec<_> = (0..queries.rows).map(|i| queries.row_owned(i)).collect();
    let mut ws = quant.workspace();
    for q in &rows {
        let _ = quant.predict_with(q, 8, 6, &mut ws);
    }
    let a0 = allocs();
    for q in &rows {
        let _ = quant.predict_with(q, 8, 6, &mut ws);
    }
    assert_eq!(
        allocs() - a0,
        0,
        "quantized steady-state serving must be allocation-free"
    );
}

fn v4_mmap_serves_exactly_and_cheaply() {
    // Dense columns (col_nnz 48) make the weight payload dominate the
    // per-chunk struct overhead, so the resident-bytes assertions have
    // real margin.
    let spec = mscm_xmr::data::synthetic::DatasetSpec {
        name: "quant-mmap",
        dim: 256,
        num_labels: 1500,
        paper_dim: 256,
        paper_labels: 0,
        query_nnz: 16,
        col_nnz: 48,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    };
    let model = mscm_xmr::data::synthetic::synth_model(&spec, 3, 0xD15C);
    let mut sh = partition(&model, 1).remove(0);
    sh.plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
    let dir = mscm_xmr::util::temp_dir("quant-mmap");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exact.v4.bin");
    save_shard_v4(&sh, &path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len() as i64;

    let before = live();
    let heap = load_shard(&path, false).unwrap();
    let heap_resident = live() - before;
    let before = live();
    let mapped = load_shard_mmap(&path, false).unwrap();
    let mmap_resident = live() - before;
    let weight_bytes: i64 = mapped
        .model
        .layers
        .iter()
        .map(|l| l.chunked.weight_bytes() as i64)
        .sum();

    // The mmap claims only hold where the zero-copy path exists; the
    // fallback (non-unix / big-endian) heap-parses by design. And under
    // MSCM_FORCE_MMAP the "heap" load above was itself mapped, so the
    // heap-vs-mmap comparison is skipped there.
    if cfg!(all(unix, target_endian = "little")) {
        assert!(
            mmap_resident < weight_bytes,
            "mmap pinned {mmap_resident} heap bytes >= {weight_bytes} weight bytes"
        );
        assert!(
            mmap_resident < file_bytes,
            "mmap pinned {mmap_resident} heap bytes >= the {file_bytes}-byte file"
        );
        let forced = std::env::var("MSCM_FORCE_MMAP").map(|v| v == "1").unwrap_or(false);
        if !forced {
            assert!(
                mmap_resident < heap_resident,
                "mmap resident {mmap_resident} >= heap resident {heap_resident}"
            );
        }
    }

    // Exact modes stay exact: heap-served, mmap-served and the
    // in-memory model all rank bitwise-identically.
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let reference = InferenceEngine::new(
        model.clone(),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
    );
    let via_heap = ShardedEngine::new(vec![heap], cfg);
    let via_mmap = ShardedEngine::new(vec![mapped], cfg);
    let mut g = common::ModelGen::new(common::base_seed() ^ 0x4444);
    let queries = g.queries(model.dim, 32);
    for i in 0..queries.rows {
        let q = queries.row_owned(i);
        let want = reference.predict(&q, 8, 6);
        assert_eq!(via_heap.predict(&q, 8, 6), want, "heap-served V4 drifted (q={i})");
        assert_eq!(via_mmap.predict(&q, 8, 6), want, "mmap-served V4 drifted (q={i})");
    }

    // Steady-state serving straight off the mapping is allocation-free.
    let m2 = load_shard_mmap(&path, false).unwrap();
    let (algo, plan) = m2.plan.clone().expect("V4 carries a plan");
    let engine = InferenceEngine::new_with_plan(
        m2.model,
        EngineConfig::new(algo, IterationMethod::Auto),
        plan,
    );
    let rows: Vec<_> = (0..queries.rows).map(|i| queries.row_owned(i)).collect();
    let mut ws = engine.workspace();
    for q in &rows {
        let _ = engine.predict_with(q, 8, 6, &mut ws);
    }
    let a0 = allocs();
    for q in &rows {
        let _ = engine.predict_with(q, 8, 6, &mut ws);
    }
    assert_eq!(
        allocs() - a0,
        0,
        "mmap steady-state serving must be allocation-free"
    );

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn quantized_and_mapped_storage_properties() {
    f16_codec_bounds();
    chunk_quantization_bounds();
    approx_precision_gate();
    v4_mmap_serves_exactly_and_cheaply();
}
