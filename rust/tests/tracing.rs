//! Distributed-tracing properties over loopback (`mscm_xmr::shard`,
//! protocol v3 + `mscm_xmr::metrics::FlightRecorder`):
//!
//! - a traced remote batch assembles a complete cross-process trace
//!   tree — one span per live shard per real network round, host
//!   decode/expand/encode inside the client's batch window, kernel-tier
//!   annotations, join-wait shares;
//! - tracing is invisible to serving: traced predictions are bitwise
//!   identical to untraced ones (and to the unsharded engine);
//! - the tail sampler provably retains injected-slow queries once its
//!   histogram is warm;
//! - chaos events (dead shard, degraded batch, speculation hits) are
//!   annotated onto the spans they happened in;
//! - a host's flight recorder round-trips over the wire `Traces` poll
//!   with the trace ids the client minted.
//!
//! Seeded via `MSCM_TEST_SEED` (`tests/common`), so the CI randomized
//! leg replays failures exactly.

mod common;

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::data::synthetic::{synth_model, synth_queries};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::metrics::{
    FlightRecorder, FlightRecorderConfig, EV_DEAD, EV_DEGRADED, EV_SPEC_HIT,
};
use mscm_xmr::shard::{
    partition, poll_traces, FaultPlan, RemoteConfig, RemoteGather, ShardHost, ShardHostConfig,
};
use mscm_xmr::tree::XmrModel;

/// Spawns one loopback host per shard of an `s`-way partition,
/// `flight_recorder` sizing each host's ring (0 = host tracing off).
fn spawn_hosts(
    model: &XmrModel,
    s: usize,
    cfg: EngineConfig,
    flight_recorder: usize,
) -> (Vec<ShardHost>, Vec<Vec<SocketAddr>>) {
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(model, s) {
        let host = ShardHost::spawn(
            shard,
            ShardHostConfig {
                engine: cfg,
                flight_recorder,
                ..Default::default()
            },
            "127.0.0.1:0",
        )
        .expect("spawn shard host");
        groups.push(vec![host.local_addr()]);
        hosts.push(host);
    }
    (hosts, groups)
}

/// A recorder that retains *every* batch (sampling gate 1-in-1), so
/// structural assertions see each trace.
fn keep_all_recorder(capacity: usize) -> Arc<FlightRecorder> {
    Arc::new(FlightRecorder::new(FlightRecorderConfig {
        capacity,
        sample_every: 1,
        ..FlightRecorderConfig::default()
    }))
}

/// The tentpole acceptance property: a traced remote batch produces a
/// cross-process trace tree covering every shard × every real round,
/// with host time inside the client's batch window and the effective
/// kernel tiers annotated.
#[test]
fn remote_trace_tree_covers_every_shard_round() {
    let sp = common::dataset_spec("tracing-tree", 96, 384);
    let seed = common::base_seed();
    let model = synth_model(&sp, 8, seed);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let s = 3usize;
    let (hosts, groups) = spawn_hosts(&model, s, cfg, 256);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            speculate: false, // every layer ships: spans = shards × depth
            ..Default::default()
        },
        None,
    )
    .expect("connect");
    let rec = keep_all_recorder(64);
    g.set_recorder(Some(Arc::clone(&rec)));
    let depth = g.depth();
    let queries = synth_queries(&sp, 10, seed ^ 0xABCD);
    for qi in 0..queries.rows {
        g.predict(&queries.row_owned(qi), 5, 5).expect("predict");
    }
    assert_eq!(rec.recorded(), queries.rows as u64, "keep-all recorder retains every batch");
    let records = rec.export();
    assert_eq!(records.len(), queries.rows.min(64));
    for r in &records {
        assert!(r.trace_id > 0, "batch trace ids are minted from 1");
        assert_eq!(r.batch, 1, "online predicts are single-query batches");
        assert_eq!(r.truncated, 0);
        assert_eq!(
            r.spans.len(),
            s * depth,
            "one span per shard per real round (no speculation)"
        );
        // Every (shard, layer) pair is present exactly once.
        for shard in 0..s as u32 {
            for layer in 0..depth as u32 {
                assert_eq!(
                    r.spans.iter().filter(|sp| sp.shard == shard && sp.layer == layer).count(),
                    1,
                    "trace {} shard {shard} layer {layer}",
                    r.trace_id
                );
            }
        }
        // Every span is a genuine sub-interval of the batch window
        // (hosts expand concurrently, so only per-span bounds — not the
        // sum — are guaranteed); join-wait is a sub-interval of its
        // round.
        for sp in &r.spans {
            assert!(sp.host.total_ns() <= r.total_ns, "host work inside the batch window");
            assert!(sp.round_ns <= r.total_ns, "round inside the batch window");
            assert!(sp.wait_ns <= sp.round_ns, "join wait inside its round");
        }
        assert!(
            r.spans.iter().any(|sp| sp.host.expand_ns > 0),
            "trace {}: traced hosts time their expansion",
            r.trace_id
        );
        // The hosts serve with engine telemetry on (the default), so
        // the expanded layers carry effective kernel-tier masks.
        assert!(
            r.spans.iter().any(|sp| sp.host.tiers != 0),
            "trace {}: no span carries a kernel-tier mask",
            r.trace_id
        );
    }
    for h in hosts {
        h.shutdown();
    }
}

/// Tracing must be invisible: a fully-traced gather and a tracing-
/// disabled gather (wire payloads byte-identical to v2) return bitwise
/// identical rankings, both equal to the unsharded engine.
#[test]
fn traced_serving_is_bitwise_identical_to_untraced() {
    let sp = common::dataset_spec("tracing-exact", 80, 256);
    let seed = common::base_seed();
    let model = synth_model(&sp, 4, seed ^ 0x77);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), cfg);
    let queries = synth_queries(&sp, 8, seed ^ 0x1234);
    for speculate in [false, true] {
        let (hosts, groups) = spawn_hosts(&model, 2, cfg, 256);
        let mut traced = RemoteGather::connect_groups(
            &groups,
            RemoteConfig {
                speculate,
                flight_recorder: 256,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        traced.set_recorder(Some(keep_all_recorder(256)));
        let mut untraced = RemoteGather::connect_groups(
            &groups,
            RemoteConfig {
                speculate,
                flight_recorder: 0,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        assert!(untraced.recorder().is_none(), "flight_recorder: 0 disables tracing");
        for qi in 0..queries.rows {
            let q = queries.row_owned(qi);
            for beam in [1usize, 3, 8] {
                let want = reference.predict(&q, beam, 5);
                assert_eq!(
                    traced.predict(&q, beam, 5).unwrap(),
                    want,
                    "traced spec={speculate} beam={beam} q={qi}"
                );
                assert_eq!(
                    untraced.predict(&q, beam, 5).unwrap(),
                    want,
                    "untraced spec={speculate} beam={beam} q={qi}"
                );
            }
        }
        assert!(traced.recorder().unwrap().recorded() > 0);
        for h in hosts {
            h.shutdown();
        }
    }
}

/// Tail retention, end to end: warm the recorder's histogram with fast
/// loopback batches under a sampling gate that would discard everything,
/// then route queries through replicas with an injected 40 ms reply
/// delay — the slow traces must be pinned into the ring.
#[test]
fn tail_sampler_retains_injected_slow_queries() {
    let sp = common::dataset_spec("tracing-tail", 64, 128);
    let seed = common::base_seed();
    let model = synth_model(&sp, 4, seed ^ 0x5109);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
    let rec = Arc::new(FlightRecorder::new(FlightRecorderConfig {
        capacity: 64,
        // The 1-in-N gate alone would retain (nearly) nothing: slow
        // traces can only survive by being pinned over the live p99.
        sample_every: 1_000_000,
        pin_quantile: 0.99,
        min_samples: 32,
    }));

    // Phase 1: warm. 80 fast batches feed the histogram past the pin
    // floor; none are slow, so the p99 settles at loopback speed.
    let (fast_hosts, fast_groups) = spawn_hosts(&model, 2, cfg, 0);
    let mut fast = RemoteGather::connect_groups(&fast_groups, RemoteConfig::default(), None).unwrap();
    fast.set_recorder(Some(Arc::clone(&rec)));
    let queries = synth_queries(&sp, 80, seed ^ 0xFA57);
    for qi in 0..queries.rows {
        fast.predict(&queries.row_owned(qi), 4, 5).unwrap();
    }
    assert_eq!(rec.observed(), 80);
    assert!(rec.pin_threshold_ms().is_some(), "pin floor met after warmup");
    // Loopback jitter can pin the odd warm batch; only the *increase*
    // under injection is asserted.
    let warm_pinned = rec.pinned();

    // Phase 2: inject. Every reply from these replicas is delayed 40 ms,
    // so a full batch (≥ 1 round) lands far beyond the warm p99.
    let mut slow_hosts = Vec::new();
    let mut slow_groups = Vec::new();
    for shard in partition(&model, 2) {
        let host = ShardHost::with_faults(
            shard,
            ShardHostConfig {
                engine: cfg,
                ..Default::default()
            },
            "127.0.0.1:0",
            FaultPlan {
                seed,
                delay_replies: Duration::from_millis(40),
                ..Default::default()
            },
        )
        .unwrap();
        slow_groups.push(vec![host.local_addr()]);
        slow_hosts.push(host);
    }
    let mut slow = RemoteGather::connect_groups(&slow_groups, RemoteConfig::default(), None).unwrap();
    slow.set_recorder(Some(Arc::clone(&rec)));
    for qi in 0..3 {
        slow.predict(&queries.row_owned(qi), 4, 5).unwrap();
    }
    assert!(
        rec.pinned() > warm_pinned,
        "an injected-slow batch must be tail-pinned (threshold {:?} ms)",
        rec.pin_threshold_ms()
    );
    let pinned: Vec<_> = rec.export().into_iter().filter(|r| r.pinned).collect();
    assert!(
        pinned.iter().any(|r| r.total_ns >= 20_000_000),
        "no exported pinned trace carries an injected-slow total: {:?}",
        pinned.iter().map(|r| r.total_ns).collect::<Vec<_>>()
    );
    for h in fast_hosts.into_iter().chain(slow_hosts) {
        h.shutdown();
    }
}

/// Chaos annotations: killing a single-replica shard under
/// `allow_partial` marks its span `dead-shard` and the batch `degraded`.
#[test]
fn dead_shard_and_degraded_batch_are_annotated() {
    let sp = common::dataset_spec("tracing-chaos", 64, 128);
    let seed = common::base_seed();
    let model = synth_model(&sp, 4, seed ^ 0xC0C0);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let (mut hosts, groups) = spawn_hosts(&model, 2, cfg, 0);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            allow_partial: true,
            speculate: false, // spec-hit bits would dirty the clean warmup trace
            round_timeout: Duration::from_millis(500),
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(5),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let rec = keep_all_recorder(16);
    g.set_recorder(Some(Arc::clone(&rec)));
    let queries = synth_queries(&sp, 4, seed ^ 0xD1E);
    g.predict(&queries.row_owned(0), 4, 5).expect("healthy warmup query");
    hosts.remove(1).shutdown(); // shard 1 has no other replica
    g.predict(&queries.row_owned(1), 4, 5).expect("degraded query must complete");
    let records = rec.export();
    let degraded = records
        .iter()
        .find(|r| r.events & EV_DEGRADED != 0)
        .expect("a degraded batch must be flagged in its trace");
    let dead_span = degraded
        .spans
        .iter()
        .find(|sp| sp.events & EV_DEAD != 0)
        .expect("the dead shard's round must carry the dead-shard event");
    assert_eq!(dead_span.shard, 1);
    assert_eq!(dead_span.host, Default::default(), "a dead round has no host span");
    // The warmup trace stays clean.
    assert!(records.iter().any(|r| r.events == 0 && r.spans.iter().all(|sp| sp.events == 0)));
    for h in hosts {
        h.shutdown();
    }
}

/// Speculation annotations: when hosts serve hints and the whole beam is
/// covered, the round that carried the hint is marked `spec-hit`.
#[test]
fn speculative_rounds_are_annotated_with_spec_hits() {
    let sp = common::dataset_spec("tracing-spec", 64, 256);
    let seed = common::base_seed();
    let model = synth_model(&sp, 4, seed ^ 0x59EC);
    assert!(model.depth() >= 2, "speculation needs at least two layers");
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let (hosts, groups) = spawn_hosts(&model, 2, cfg, 0);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            speculate: true,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let rec = keep_all_recorder(16);
    g.set_recorder(Some(Arc::clone(&rec)));
    let queries = synth_queries(&sp, 4, seed ^ 0xBEE);
    for qi in 0..queries.rows {
        g.predict(&queries.row_owned(qi), 4, 5).unwrap();
    }
    let records = rec.export();
    assert!(
        records.iter().any(|r| r.spans.iter().any(|sp| sp.events & EV_SPEC_HIT != 0)),
        "cooperating hosts must produce spec-hit rounds"
    );
    // A saved round ships no frames, so a spec-hit trace has fewer
    // spans than shards × depth.
    let depth = g.depth();
    assert!(
        records.iter().any(|r| r.spans.len() < 2 * depth),
        "no trace saved a network round of spans"
    );
    for h in hosts {
        h.shutdown();
    }
}

/// The wire export: host-side flight recorders answer the `Traces` poll
/// with the rounds they retained, carrying the client-minted trace ids —
/// and polling is stable and side-effect free.
#[test]
fn host_flight_recorder_round_trips_over_the_traces_poll() {
    let sp = common::dataset_spec("tracing-poll", 80, 256);
    let seed = common::base_seed();
    let model = synth_model(&sp, 4, seed ^ 0x9011);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    // Host rings sample 1-in-8 (the default), so drive enough rounds
    // that each host retains several records.
    let (hosts, groups) = spawn_hosts(&model, 2, cfg, 256);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            speculate: false,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let rec = keep_all_recorder(256);
    g.set_recorder(Some(Arc::clone(&rec)));
    let queries = synth_queries(&sp, 40, seed ^ 0x70CC);
    for qi in 0..queries.rows {
        g.predict(&queries.row_owned(qi), 4, 5).unwrap();
    }
    // Every batch was traced and the keep-all client ring is big enough,
    // so the client's export holds the full minted id set.
    let client_ids: Vec<u64> = rec.export().iter().map(|r| r.trace_id).collect();
    assert_eq!(client_ids.len(), queries.rows);

    let via_gather = g.poll_shard_traces(0).expect("poll shard 0");
    assert!(!via_gather.is_empty(), "host 0 retained no rounds");
    for r in &via_gather {
        assert!(client_ids.contains(&r.trace_id), "host record {} has a foreign id", r.trace_id);
        assert_eq!(r.spans.len(), 1, "hosts record one span per round");
        let sp0 = &r.spans[0];
        assert_eq!(sp0.shard, 0);
        assert!((sp0.layer as usize) < g.depth());
        // round_ns is the decode+expand+encode sum; the record total
        // additionally covers validation and the reply write.
        assert_eq!(sp0.round_ns, sp0.host.total_ns());
        assert!(sp0.round_ns <= r.total_ns, "host span inside the host record window");
    }
    assert!(
        via_gather.iter().any(|r| r.spans[0].host.expand_ns > 0),
        "host rounds time their expansion"
    );
    assert!(
        via_gather.iter().any(|r| r.spans[0].host.encode_ns > 0),
        "encode time is backpatched into the retained span"
    );
    // A fresh-connection poll (the `metrics --traces` path) sees the
    // same ring, and polling twice returns identical records — polls
    // are not themselves recorded.
    let direct = poll_traces(groups[0][0], &RemoteConfig::default()).expect("direct poll");
    assert_eq!(direct, via_gather);
    assert_eq!(g.poll_shard_traces(0).unwrap(), via_gather);

    // A host spawned with its recorder disabled answers with an empty
    // dump instead of an error.
    let (off_hosts, off_groups) = spawn_hosts(&model, 1, cfg, 0);
    assert!(poll_traces(off_groups[0][0], &RemoteConfig::default()).unwrap().is_empty());
    for h in hosts.into_iter().chain(off_hosts) {
        h.shutdown();
    }
}
