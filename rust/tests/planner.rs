//! Property tests for the per-chunk kernel planner's central claims:
//!
//! 1. **Exactness** — `IterationMethod::Auto` (kernel *and* storage
//!    selection) is bitwise identical to every fixed method, for both
//!    masked-matmul algorithms, online and batch, unsharded and sharded
//!    (S ∈ {1, 4}), with and without timing calibration — over the
//!    shared seeded model generator (`tests/common`, `MSCM_TEST_SEED`
//!    replayable).
//! 2. **Memory** — side indexes are materialized only for chunks whose
//!    planned kernel needs them: on a mixed-density model the auto
//!    engine's `side_index_bytes` is strictly below fixed `hash`'s.
//! 3. **Persistence** — plans (layouts included) survive the `MSCMXMR3`
//!    shard envelope and are served verbatim (no re-planning at load).

mod common;

use mscm_xmr::data::synthetic::synth_queries;
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::shard::{load_shards, partition, save_shards, ShardedEngine};

/// Mixed-density skewed tree: the shape where the planner actually mixes
/// methods and layouts.
fn skewed_model() -> mscm_xmr::XmrModel {
    common::skewed_model(96, 300, 8, 0xBEEF)
}

#[test]
fn auto_is_bitwise_identical_to_every_fixed_method() {
    common::run_cases(8, |_, case| {
        let rows = case.query_rows();
        for algo in MatmulAlgo::ALL {
            let auto = InferenceEngine::new(
                case.model.clone(),
                EngineConfig::new(algo, IterationMethod::Auto),
            );
            for iter in IterationMethod::ALL {
                let fixed =
                    InferenceEngine::new(case.model.clone(), EngineConfig::new(algo, iter));
                for beam in [1usize, 3, 10] {
                    // batch (chunk-order path active when n > 1)
                    assert_eq!(
                        auto.predict_batch(&case.queries, beam, 5),
                        fixed.predict_batch(&case.queries, beam, 5),
                        "batch {algo:?}/{iter:?} beam={beam} ({})",
                        case.shape
                    );
                    // online, workspace reused like a server
                    let mut ws = auto.workspace();
                    for (qi, q) in rows.iter().enumerate() {
                        assert_eq!(
                            auto.predict_with(q, beam, 5, &mut ws),
                            &fixed.predict(q, beam, 5)[..],
                            "online {algo:?}/{iter:?} beam={beam} q={qi} ({})",
                            case.shape
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn sharded_auto_is_bitwise_identical() {
    common::run_cases(6, |_, case| {
        let rows = case.query_rows();
        for algo in MatmulAlgo::ALL {
            let reference = InferenceEngine::new(
                case.model.clone(),
                EngineConfig::new(algo, IterationMethod::MarchingPointers),
            );
            for s in [1usize, 4] {
                let sharded = ShardedEngine::from_model(
                    &case.model,
                    s,
                    EngineConfig::new(algo, IterationMethod::Auto),
                );
                for beam in [1usize, 3, 10] {
                    // online
                    for (qi, q) in rows.iter().enumerate() {
                        assert_eq!(
                            sharded.predict(q, beam, 5),
                            reference.predict(q, beam, 5),
                            "online {algo:?} S={s} beam={beam} q={qi} ({})",
                            case.shape
                        );
                    }
                    // batch scatter-gather
                    let batch = sharded.predict_batch(&case.queries, beam, 5, false);
                    let want = reference.predict_batch(&case.queries, beam, 5);
                    assert_eq!(batch, want, "batch {algo:?} S={s} beam={beam} ({})", case.shape);
                }
            }
        }
    });
}

#[test]
fn calibrated_plans_stay_exact() {
    // Calibration fits timing constants, so the *plan* may differ run to
    // run — predictions must not.
    common::run_cases(4, |_, case| {
        let pc = PlannerConfig {
            calibrate: 6,
            query_nnz_hint: 12,
            batch_hint: 8,
            ..Default::default()
        };
        let auto = InferenceEngine::new_with_planner(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
            &pc,
        );
        let fixed = InferenceEngine::new(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        );
        assert_eq!(
            auto.predict_batch(&case.queries, 5, 5),
            fixed.predict_batch(&case.queries, 5, 5),
            "{}",
            case.shape
        );
    });
}

#[test]
fn auto_side_indexes_are_strictly_below_fixed_hash() {
    // Mixed-density model: the plan sends the tiny bottom chunks to
    // pointer-walk kernels, so their row maps are never built — strictly
    // fewer side-index bytes than the fixed hash configuration, which
    // must index every chunk.
    let model = skewed_model();
    let hash = InferenceEngine::new(
        model.clone(),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );
    let auto = InferenceEngine::new(
        model.clone(),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
    );
    assert!(
        auto.side_index_bytes() < hash.side_index_bytes(),
        "auto {} must be strictly below fixed hash {} (plan:\n{})",
        auto.side_index_bytes(),
        hash.side_index_bytes(),
        auto.plan().summary()
    );
    // Same claim on the baseline algo's per-column maps.
    let hash_b = InferenceEngine::new(
        model.clone(),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Hash),
    );
    let auto_b = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Auto),
    );
    assert!(
        auto_b.side_index_bytes() < hash_b.side_index_bytes(),
        "baseline: auto {} vs hash {}",
        auto_b.side_index_bytes(),
        hash_b.side_index_bytes()
    );
}

#[test]
fn plans_round_trip_through_the_shard_envelope_and_serve() {
    let model = skewed_model();
    let sp = common::dataset_spec("planner-prop", 96, 300);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let mut shards = partition(&model, 3);
    let pc = PlannerConfig {
        query_nnz_hint: sp.query_nnz,
        ..Default::default()
    };
    for s in &mut shards {
        s.plan_auto(MatmulAlgo::Mscm, &pc);
    }
    let plans: Vec<KernelPlan> = shards.iter().map(|s| s.plan.clone().unwrap().1).collect();
    let dir = mscm_xmr::util::temp_dir("planner-prop-io");
    save_shards(&shards, &dir).unwrap();
    let loaded = load_shards(&dir, false).unwrap();
    for (s, want) in loaded.iter().zip(&plans) {
        let (algo, plan) = s.plan.as_ref().expect("stored plan");
        assert_eq!(*algo, MatmulAlgo::Mscm, "shard {}", s.spec.shard_id);
        assert_eq!(plan, want, "shard {}", s.spec.shard_id);
    }
    // The engine serves the stored plans verbatim (stored storage
    // layouts applied) and stays exact.
    let sharded = ShardedEngine::new(loaded, cfg);
    for (s, want) in plans.iter().enumerate() {
        assert_eq!(sharded.shard_engine(s).plan().as_ref(), want, "shard {s}");
    }
    let reference = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );
    let queries = synth_queries(&sp, 6, 3);
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(sharded.predict(&q, 4, 5), reference.predict(&q, 4, 5), "q={qi}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn planner_hints_change_plans_but_never_results() {
    // Online-tuned and batch-tuned plans may disagree per chunk (and per
    // layout); both must produce the one true answer.
    common::run_cases(4, |_, case| {
        let online_pc = PlannerConfig {
            batch_hint: 1,
            query_nnz_hint: 100,
            ..Default::default()
        };
        let batch_pc = PlannerConfig {
            batch_hint: 64,
            query_nnz_hint: 8,
            ..Default::default()
        };
        let a = InferenceEngine::new_with_planner(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
            &online_pc,
        );
        let b = InferenceEngine::new_with_planner(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
            &batch_pc,
        );
        assert_eq!(
            a.predict_batch(&case.queries, 5, 5),
            b.predict_batch(&case.queries, 5, 5),
            "{}",
            case.shape
        );
    });
}
