//! The zero-allocation invariant of the serving hot path.
//!
//! A counting `#[global_allocator]` shim wraps the system allocator and
//! counts every `alloc`/`realloc` in the process. After a warmup call
//! sizes the flat arenas, the steady-state hot paths must not touch the
//! allocator at all:
//!
//! - online `InferenceEngine::predict_with` (workspace-resident query
//!   row + output buffer),
//! - batch `InferenceEngine::predict_range` with pooled output rows
//!   (exercises the counting-sort chunk ordering, `n > 1`),
//! - the in-process sharded layer-sync rounds
//!   (`ShardedEngine::predict_with` / `predict_batch_into` against a
//!   pooled `GatherArena`),
//! - all of the above with engine telemetry enabled (`with_metrics`):
//!   the per-layer timing + plan-drift attribution must be free of
//!   steady-state allocations, and the disabled trace path has no hook
//!   on the hot path at all,
//! - `FlightRecorder::record` itself (slot-pooled ring, spans refilled
//!   in place),
//! - the remote loopback rounds (`RemoteGather::predict_with` against
//!   in-process `ShardHost`s) with tracing fully on: client scatter /
//!   join / trace assembly *and* the hosts' decode / expand / encode /
//!   recorder writes all land in the same process-wide tally, and the
//!   whole traced round trip must stay at zero once warm.
//!
//! The full coordinator round trip (`query_blocking`) cannot be zero —
//! each request inherently allocates its reply channel, queue nodes and
//! the client-owned ranking — so it is *bounded* instead: the pooled
//! round-buffer protocol keeps the per-query count at a small constant,
//! where the pre-pooling code allocated fresh nested beam/candidate
//! vectors on every `layer × shard` round. The bound is measured with
//! the flight recorder on (the default), so trace assembly rides inside
//! the same constant.
//!
//! Everything runs inside ONE `#[test]` so no sibling test thread can
//! pollute the process-wide counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, KernelTier, MatmulAlgo, Prediction,
};
use mscm_xmr::metrics::{FlightRecorder, FlightRecorderConfig, HostSpan, RoundSpan};
use mscm_xmr::shard::{
    partition, GatherArena, RemoteConfig, RemoteGather, ShardHost, ShardHostConfig,
    ShardedCoordinator, ShardedCoordinatorConfig, ShardedEngine,
};
use mscm_xmr::sparse::{ChunkStorage, SparseVec};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counts allocator entries (alloc + realloc + alloc_zeroed); frees are
/// irrelevant to the invariant.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn spec() -> DatasetSpec {
    DatasetSpec {
        name: "alloc-prop",
        dim: 64,
        num_labels: 256,
        paper_dim: 64,
        paper_labels: 0,
        query_nnz: 8,
        col_nnz: 6,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

/// MSCM × {marching, binary} is the minimum the invariant demands; the
/// other two MSCM iterators, the baseline and the planner's `Auto` ride
/// along since the arenas are shared code. `Auto` additionally pins that
/// the per-chunk plan lookup (a slice index into the resolved
/// `KernelPlan`) never allocates in the hot loop — planning and
/// side-index construction happen once, at engine build.
fn zero_alloc_configs() -> Vec<EngineConfig> {
    vec![
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Auto),
    ]
}

#[test]
fn steady_state_hot_paths_do_not_allocate() {
    let sp = spec();
    let model = synth_model(&sp, 4, 0xA110C);
    let x = synth_queries(&sp, 16, 0x5EED);
    let queries: Vec<SparseVec> = (0..x.rows).map(|i| x.row_owned(i)).collect();

    // --- online predict_with: zero allocations after warmup ---
    for cfg in zero_alloc_configs() {
        let engine = InferenceEngine::new(model.clone(), cfg);
        let mut ws = engine.workspace();
        for _ in 0..2 {
            for q in &queries {
                std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
            }
        }
        let before = allocs();
        for q in &queries {
            std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "online predict_with allocated {delta}x after warmup ({})",
            cfg.label()
        );
    }

    // --- batch predict_range (n > 1: counting sort active): zero ---
    for cfg in zero_alloc_configs() {
        let engine = InferenceEngine::new(model.clone(), cfg);
        let mut ws = engine.workspace();
        let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); x.rows];
        for _ in 0..2 {
            engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
        }
        let before = allocs();
        engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "batch predict_range allocated {delta}x after warmup ({})",
            cfg.label()
        );
    }

    // --- forced DenseRows / Merged weight layouts: the same zero bar.
    // DenseRows runs the direct-probe kernel (no scratch to load);
    // Merged runs every kernel through store-backed views — neither may
    // touch the allocator once warm. ---
    for storage in [ChunkStorage::DenseRows, ChunkStorage::Merged] {
        for iter in [
            IterationMethod::MarchingPointers,
            IterationMethod::DenseLookup,
        ] {
            let cfg = EngineConfig::new(MatmulAlgo::Mscm, iter);
            let plan = KernelPlan::uniform(&model, iter).with_uniform_storage(storage);
            let engine = InferenceEngine::new_with_plan(model.clone(), cfg, plan);
            let mut ws = engine.workspace();
            let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); x.rows];
            for _ in 0..2 {
                for q in &queries {
                    std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
                }
                engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
            }
            let before = allocs();
            for q in &queries {
                std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
            }
            engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
            let delta = allocs() - before;
            assert_eq!(
                delta, 0,
                "{storage:?}/{iter:?} hot path allocated {delta}x after warmup"
            );
        }
    }

    // --- forced SIMD tier over every layout: the same zero bar. The
    // tier dispatch is a per-block branch into kernels that reuse the
    // exact scalar-path buffers (gathers read in place, emits write the
    // caller's slice); on non-vector hardware the branch degrades to the
    // scalar kernels — either way nothing may allocate once warm. ---
    for storage in ChunkStorage::ALL {
        for iter in [
            IterationMethod::MarchingPointers,
            IterationMethod::DenseLookup,
        ] {
            let cfg = EngineConfig::new(MatmulAlgo::Mscm, iter);
            let plan = KernelPlan::uniform(&model, iter)
                .with_uniform_storage(storage)
                .with_uniform_tier(KernelTier::Simd);
            let engine = InferenceEngine::new_with_plan(model.clone(), cfg, plan);
            let mut ws = engine.workspace();
            let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); x.rows];
            for _ in 0..2 {
                for q in &queries {
                    std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
                }
                engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
            }
            let before = allocs();
            for q in &queries {
                std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
            }
            engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
            let delta = allocs() - before;
            assert_eq!(
                delta, 0,
                "SIMD-tier {storage:?}/{iter:?} hot path allocated {delta}x after warmup"
            );
        }
    }

    // --- in-process sharded layer-sync rounds: zero ---
    for cfg in [
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
    ] {
        let sharded = ShardedEngine::from_model(&model, 4, cfg);
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        for _ in 0..2 {
            for q in &queries {
                std::hint::black_box(sharded.predict_with(q, 10, 5, &mut wss, &mut arena));
            }
            sharded.predict_batch_into(&x, 10, 5, false, &mut wss, &mut arena);
        }
        let before = allocs();
        for q in &queries {
            std::hint::black_box(sharded.predict_with(q, 10, 5, &mut wss, &mut arena));
        }
        let online_delta = allocs() - before;
        assert_eq!(
            online_delta, 0,
            "sharded online rounds allocated {online_delta}x after warmup ({})",
            cfg.label()
        );
        let before = allocs();
        sharded.predict_batch_into(&x, 10, 5, false, &mut wss, &mut arena);
        let batch_delta = allocs() - before;
        assert_eq!(
            batch_delta, 0,
            "sharded batch rounds allocated {batch_delta}x after warmup ({})",
            cfg.label()
        );
    }

    // --- metrics enabled: observability must not bend the zero bar ---
    // `EngineMetrics::record_layer` is one `Instant` pair per layer
    // slice plus stack accumulation flushed as relaxed atomic adds; the
    // attribution tables are frozen at `with_metrics` time. Per-query
    // tracing (`predict_traced`) is a separate opt-in cold path — with
    // tracing disabled there is no hook on the hot path at all, so the
    // metered runs below are the entire observability surface to bound.
    {
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
        let engine = InferenceEngine::new(model.clone(), cfg).with_metrics();
        let mut ws = engine.workspace();
        let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); x.rows];
        for _ in 0..2 {
            for q in &queries {
                std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
            }
            engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
        }
        let before = allocs();
        for q in &queries {
            std::hint::black_box(engine.predict_with(q, 10, 5, &mut ws));
        }
        engine.predict_range(&x, 0, x.rows, 10, 5, &mut ws, &mut out);
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "metered engine hot path allocated {delta}x after warmup"
        );
        // The telemetry actually recorded through the measured window.
        let m = engine.metrics().expect("metrics attached");
        assert!(m.total_ns() > 0, "metered run recorded no layer time");
        let drift = m.plan_drift();
        assert!(
            drift.cells.iter().any(|c| c.blocks > 0),
            "drift join saw no blocks"
        );

        let sharded = ShardedEngine::from_model(&model, 4, cfg).with_metrics();
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        for _ in 0..2 {
            for q in &queries {
                std::hint::black_box(sharded.predict_with(q, 10, 5, &mut wss, &mut arena));
            }
            sharded.predict_batch_into(&x, 10, 5, false, &mut wss, &mut arena);
        }
        let before = allocs();
        for q in &queries {
            std::hint::black_box(sharded.predict_with(q, 10, 5, &mut wss, &mut arena));
        }
        sharded.predict_batch_into(&x, 10, 5, false, &mut wss, &mut arena);
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "metered sharded rounds allocated {delta}x after warmup"
        );
        assert!(
            (0..4).all(|s| sharded.shard_metrics(s).is_some_and(|m| m.total_ns() > 0)),
            "a metered shard recorded no layer time"
        );
    }

    // --- flight recorder recording: zero ---
    // Slots (and their span vectors) are pre-sized at construction;
    // `record` claims a slot with a try_lock and refills the pooled
    // record in place. The measured loop wraps the ring many times and
    // crosses the pin-threshold warm floor, so sampled writes, pinned-
    // slot protection and threshold reads are all inside the window.
    {
        let rec = FlightRecorder::new(FlightRecorderConfig {
            capacity: 16,
            sample_every: 2,
            ..Default::default()
        });
        let span = RoundSpan {
            shard: 1,
            layer: 2,
            tx_ns: 1_000,
            round_ns: 90_000,
            wait_ns: 4_000,
            host: HostSpan {
                decode_ns: 2_000,
                expand_ns: 60_000,
                encode_ns: 3_000,
                tiers: 0b01,
            },
            events: 0,
        };
        for i in 0..64u64 {
            rec.record(Duration::from_micros(400 + i % 7), |r| {
                r.trace_id = i;
                for _ in 0..8 {
                    r.push_span(span);
                }
            });
        }
        let before = allocs();
        for i in 0..256u64 {
            rec.record(Duration::from_micros(400 + i % 7), |r| {
                r.trace_id = 1_000 + i;
                for _ in 0..8 {
                    r.push_span(span);
                }
            });
        }
        let delta = allocs() - before;
        assert_eq!(delta, 0, "flight recorder recording allocated {delta}x");
        assert!(rec.recorded() > 0, "nothing retained through the measured loop");
    }

    // --- remote loopback rounds, tracing fully on: zero ---
    // The hosts run in-process threads, so the *entire* traced round
    // trip counts here: client encode/scatter/join/span assembly and
    // recorder write, plus each host's decode, expansion, speculation,
    // reply encode, backpatch and its own recorder write. Warmup passes
    // over the same query set size every pooled codec buffer to its
    // maximum, after which traced serving must not touch the allocator.
    {
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
        let mut hosts = Vec::new();
        let mut groups = Vec::new();
        for shard in partition(&model, 2) {
            let host = ShardHost::spawn(
                shard,
                ShardHostConfig {
                    engine: cfg,
                    ..Default::default()
                },
                "127.0.0.1:0",
            )
            .expect("spawn loopback host");
            groups.push(vec![host.local_addr()]);
            hosts.push(host);
        }
        let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None)
            .expect("connect loopback hosts");
        assert!(g.recorder().is_some(), "tracing is on by default");
        for _ in 0..3 {
            for q in &queries {
                std::hint::black_box(g.predict_with(q, 10, 5).expect("warmup round"));
            }
        }
        let before = allocs();
        for q in &queries {
            std::hint::black_box(g.predict_with(q, 10, 5).expect("measured round"));
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "traced remote rounds allocated {delta}x after warmup"
        );
        let rec = g.recorder().expect("recorder attached");
        assert!(rec.observed() > 0, "recorder observed no batches");
        assert!(rec.recorded() > 0, "recorder retained no batches");
        for h in hosts {
            h.shutdown();
        }
    }

    // --- coordinator round trip: bounded, not zero ---
    // Per request the protocol must allocate only channel/queue nodes and
    // the client-owned reply. Before round-buffer pooling, every
    // layer × shard round built fresh nested beam/candidate vectors and
    // the per-batch query rows were cloned — at depth 4 × 4 shards that
    // alone blew well past this bound.
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch);
    let engine = Arc::new(ShardedEngine::from_model(&model, 4, cfg));
    let coord = ShardedCoordinator::start(
        engine,
        ShardedCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 1,
                max_batch: 8,
                max_batch_delay: Duration::from_micros(50),
                beam: 10,
                topk: 5,
                ..Default::default()
            },
            shard_workers: 1,
            // Default: the flight recorder is on, so the measured bound
            // below covers batch tracing (pooled spans + ring write).
            ..Default::default()
        },
    );
    for q in &queries {
        coord.query_blocking(q.clone()).expect("warmup reply");
    }
    let before = allocs();
    for q in &queries {
        coord.query_blocking(q.clone()).expect("measured reply");
    }
    // Sequential blocking submission makes every batch deterministically
    // size 1 (no timing dependence): the measured count is the fixed
    // per-request protocol cost — reply channel, queue nodes, one
    // channel per layer round, the client-owned ranking — roughly 25–35
    // allocations here. The bound leaves headroom for std::sync::mpsc
    // internals shifting across toolchains while still catching a
    // return of the per-round nested-buffer churn (which added ~60+ at
    // depth 4 × 4 shards).
    let per_query = (allocs() - before) / queries.len() as u64;
    assert!(
        per_query <= 96,
        "coordinator round trip allocated {per_query}x per query (pooling regressed?)"
    );
    // Tracing actually ran inside the measured bound.
    let rec = coord.flight_recorder().expect("recorder on by default");
    assert!(rec.observed() > 0, "coordinator recorder observed no batches");
    coord.shutdown();
}
