//! Property tests for the sharding subsystem's central claim: the
//! scatter-gather coordinator is **exact** — sharded top-k equals the
//! unsharded engine bit for bit, for every shard count S ∈ {1, 2, 4, 7},
//! both masked-matmul algorithms and all four iteration methods, across
//! beam widths from greedy (1) to exhaustive.
//!
//! Randomized models/queries come from the shared seeded harness in
//! `tests/common` (`MSCM_TEST_SEED` replayable); models with few root
//! children exercise the clamp-to-root-children partition path
//! automatically.

mod common;

use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::synthetic::synth_queries;
use mscm_xmr::inference::{EngineConfig, InferenceEngine};
use mscm_xmr::shard::{
    load_shards, partition, save_shards, ShardedCoordinator, ShardedCoordinatorConfig,
    ShardedEngine,
};
use mscm_xmr::sparse::SparseVec;
use mscm_xmr::util::Rng;

#[test]
fn sharded_topk_is_bitwise_identical_to_unsharded() {
    common::run_cases(6, |case_id, case| {
        // The full config grid alternates per case to bound runtime;
        // every configuration is covered across the default 6 cases.
        for (ci, cfg) in EngineConfig::all().into_iter().enumerate() {
            if (ci + case_id as usize) % 2 == 1 {
                continue;
            }
            let reference = InferenceEngine::new(case.model.clone(), cfg);
            let rows = case.query_rows();
            for s in [1usize, 2, 4, 7] {
                let sharded = ShardedEngine::from_model(&case.model, s, cfg);
                for beam in [1usize, 3, 10, 100] {
                    for (qi, q) in rows.iter().enumerate() {
                        let want = reference.predict(q, beam, 10);
                        let got = sharded.predict(q, beam, 10);
                        assert_eq!(
                            got,
                            want,
                            "{} S={s} beam={beam} q={qi} ({})",
                            cfg.label(),
                            case.shape
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn empty_and_degenerate_queries_stay_exact() {
    common::run_cases(4, |_, case| {
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(case.model.clone(), cfg);
            let sharded = ShardedEngine::from_model(&case.model, 4, cfg);
            // all-zero query: every activation is sigma(0)
            let empty = SparseVec::new();
            assert_eq!(
                sharded.predict(&empty, 5, 5),
                reference.predict(&empty, 5, 5),
                "{} ({})",
                cfg.label(),
                case.shape
            );
            // single-feature queries (the last one beyond most supports)
            for f in [0u32, 7, (case.model.dim - 1) as u32] {
                let q = SparseVec::from_pairs(vec![(f, 1.5)]);
                assert_eq!(
                    sharded.predict(&q, 2, 3),
                    reference.predict(&q, 2, 3),
                    "{} f={f} ({})",
                    cfg.label(),
                    case.shape
                );
            }
        }
    });
}

#[test]
fn disk_round_trip_preserves_exactness() {
    common::run_cases(3, |case_id, case| {
        let cfg = EngineConfig::all()[5]; // MSCM + binary search
        let reference = InferenceEngine::new(case.model.clone(), cfg);
        let dir = mscm_xmr::util::temp_dir(&format!("shard-prop-io-{case_id}"));
        save_shards(&partition(&case.model, 4), &dir).unwrap();
        let sharded = ShardedEngine::new(load_shards(&dir, false).unwrap(), cfg);
        let rows = case.query_rows();
        for (qi, q) in rows.iter().enumerate() {
            assert_eq!(
                sharded.predict(q, 5, 5),
                reference.predict(q, 5, 5),
                "q={qi} ({})",
                case.shape
            );
        }
        std::fs::remove_dir_all(dir).ok();
    });
}

#[test]
fn sharded_coordinator_serves_exact_results() {
    // Fixed-shape model (the coordinator path wants a steady stream of
    // non-trivial queries, not a degenerate random case).
    let sp = common::dataset_spec("shard-prop", 120, 512);
    let model = mscm_xmr::data::synthetic::synth_model(&sp, 8, 0xA11CE);
    let cfg = EngineConfig::all()[6]; // MSCM + hash
    let reference = InferenceEngine::new(model.clone(), cfg);
    let engine = Arc::new(ShardedEngine::from_model(&model, 4, cfg));
    let coord = ShardedCoordinator::start(
        engine,
        ShardedCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 16,
                max_batch_delay: Duration::from_micros(300),
                beam: 5,
                topk: 5,
                ..Default::default()
            },
            shard_workers: 2,
        },
    );
    let queries = synth_queries(&sp, 64, 1234);
    let mut rng = Rng::seed_from_u64(5);
    let mut pending = Vec::new();
    for i in 0..queries.rows {
        let q = queries.row_owned(i);
        // jitter submissions so batches form with mixed sizes
        if rng.gen_bool(0.2) {
            std::thread::sleep(Duration::from_micros(100));
        }
        let (id, rx) = coord.submit(q.clone()).expect("submit");
        pending.push((id, rx, q));
    }
    for (i, (id, rx, q)) in pending.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        assert_eq!(resp.id, id);
        assert_eq!(resp.predictions, reference.predict(&q, 5, 5), "query {i}");
    }
    coord.shutdown();
}
