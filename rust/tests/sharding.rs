//! Property tests for the sharding subsystem's central claim: the
//! scatter-gather coordinator is **exact** — sharded top-k equals the
//! unsharded engine bit for bit, for every shard count S ∈ {1, 2, 4, 7},
//! both masked-matmul algorithms and all four iteration methods, across
//! beam widths from greedy (1) to exhaustive.

use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine};
use mscm_xmr::shard::{
    load_shards, partition, save_shards, ShardedCoordinator, ShardedCoordinatorConfig,
    ShardedEngine,
};
use mscm_xmr::sparse::SparseVec;
use mscm_xmr::util::Rng;

fn spec(dim: usize, labels: usize) -> DatasetSpec {
    DatasetSpec {
        name: "shard-prop",
        dim,
        num_labels: labels,
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: 10,
        col_nnz: 6,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

/// Model shapes: (spec, branching). The first has 8 root children (so
/// S = 7 is a genuine uneven partition), the second only 3 (so S = 7
/// exercises the clamp-to-root-children path).
fn model_cases() -> Vec<(DatasetSpec, usize, u64)> {
    vec![
        (spec(120, 512), 8, 0xA11CE),
        (spec(64, 81), 3, 0xB0B),
    ]
}

#[test]
fn sharded_topk_is_bitwise_identical_to_unsharded() {
    for (sp, branching, seed) in model_cases() {
        let model = synth_model(&sp, branching, seed);
        let queries = synth_queries(&sp, 8, seed ^ 0x5EED);
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(model.clone(), cfg);
            for s in [1usize, 2, 4, 7] {
                let sharded = ShardedEngine::from_model(&model, s, cfg);
                for beam in [1usize, 3, 10, 100] {
                    for qi in 0..queries.rows {
                        let q = queries.row_owned(qi);
                        let want = reference.predict(&q, beam, 10);
                        let got = sharded.predict(&q, beam, 10);
                        assert_eq!(
                            got,
                            want,
                            "{} S={s} beam={beam} q={qi} ({})",
                            cfg.label(),
                            sp.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn empty_and_degenerate_queries_stay_exact() {
    let (sp, branching, seed) = model_cases().remove(0);
    let model = synth_model(&sp, branching, seed);
    for cfg in EngineConfig::all() {
        let reference = InferenceEngine::new(model.clone(), cfg);
        let sharded = ShardedEngine::from_model(&model, 4, cfg);
        // all-zero query: every activation is sigma(0)
        let empty = SparseVec::new();
        assert_eq!(sharded.predict(&empty, 5, 5), reference.predict(&empty, 5, 5));
        // single-feature queries
        for f in [0u32, 7, 100] {
            let q = SparseVec::from_pairs(vec![(f, 1.5)]);
            assert_eq!(
                sharded.predict(&q, 2, 3),
                reference.predict(&q, 2, 3),
                "{} f={f}",
                cfg.label()
            );
        }
    }
}

#[test]
fn disk_round_trip_preserves_exactness() {
    let (sp, branching, seed) = model_cases().remove(0);
    let model = synth_model(&sp, branching, seed);
    let cfg = EngineConfig::all()[5]; // MSCM + binary search
    let reference = InferenceEngine::new(model.clone(), cfg);
    let dir = mscm_xmr::util::temp_dir("shard-prop-io");
    save_shards(&partition(&model, 4), &dir).unwrap();
    let sharded = ShardedEngine::new(load_shards(&dir, false).unwrap(), cfg);
    let queries = synth_queries(&sp, 6, 99);
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(sharded.predict(&q, 5, 5), reference.predict(&q, 5, 5), "q={qi}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn sharded_coordinator_serves_exact_results() {
    let (sp, branching, seed) = model_cases().remove(0);
    let model = synth_model(&sp, branching, seed);
    let cfg = EngineConfig::all()[6]; // MSCM + hash
    let reference = InferenceEngine::new(model.clone(), cfg);
    let engine = Arc::new(ShardedEngine::from_model(&model, 4, cfg));
    let coord = ShardedCoordinator::start(
        engine,
        ShardedCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 16,
                max_batch_delay: Duration::from_micros(300),
                beam: 5,
                topk: 5,
                ..Default::default()
            },
            shard_workers: 2,
        },
    );
    let queries = synth_queries(&sp, 64, 1234);
    let mut rng = Rng::seed_from_u64(5);
    let mut pending = Vec::new();
    for i in 0..queries.rows {
        let q = queries.row_owned(i);
        // jitter submissions so batches form with mixed sizes
        if rng.gen_bool(0.2) {
            std::thread::sleep(Duration::from_micros(100));
        }
        let (id, rx) = coord.submit(q.clone()).expect("submit");
        pending.push((id, rx, q));
    }
    for (i, (id, rx, q)) in pending.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        assert_eq!(resp.id, id);
        assert_eq!(resp.predictions, reference.predict(&q, 5, 5), "query {i}");
    }
    coord.shutdown();
}
