//! Integration: the full model-production pipeline — corpus → TFIDF →
//! train → save → load → serve through the coordinator — and the
//! NapkinXC comparator on the same trained model.

use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::coordinator::{Coordinator, CoordinatorConfig};
use mscm_xmr::data::corpus::{Corpus, CorpusSpec};
use mscm_xmr::data::svmlight::{load_svmlight, save_svmlight, SvmlightData};
use mscm_xmr::inference::napkinxc::NapkinXcEngine;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::train::{train_model, RankerParams, Tfidf};
use mscm_xmr::tree::{load_model, save_model};

#[test]
fn corpus_to_serving_round_trip() {
    let spec = CorpusSpec {
        vocab: 1_500,
        topics: 32,
        docs: 800,
        max_labels: 1,
        seed: 5,
        ..Default::default()
    };
    let corpus = Corpus::generate(spec.clone());
    let tfidf = Tfidf::fit(&corpus.docs, spec.vocab);
    let x = tfidf.transform(&corpus.docs);

    // persist the dataset through the svmlight substrate too
    let dir = mscm_xmr::util::temp_dir("pipeline");
    let data_path = dir.join("corpus.svm");
    save_svmlight(
        &SvmlightData {
            features: x.clone(),
            labels: corpus.labels.clone(),
            num_labels: spec.topics,
        },
        &data_path,
    )
    .unwrap();
    let reloaded = load_svmlight(&data_path).unwrap();
    assert_eq!(reloaded.features.rows, x.rows);

    let trained = train_model(
        &reloaded.features,
        &reloaded.labels,
        spec.topics,
        4,
        &RankerParams::default(),
        3,
    );
    let model_path = dir.join("model.bin");
    save_model(&trained.model, &model_path).unwrap();
    let model = load_model(&model_path, true).unwrap();
    assert_eq!(model.num_labels(), spec.topics);

    // serve through the coordinator and check quality end to end
    let engine = Arc::new(InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
    ));
    let coord = Coordinator::start(
        Arc::clone(&engine),
        CoordinatorConfig {
            workers: 2,
            max_batch: 16,
            max_batch_delay: Duration::from_micros(200),
            beam: 6,
            topk: 3,
            ..Default::default()
        },
    );
    let mut hits = 0;
    let probes = 100;
    for i in 0..probes {
        let q = tfidf.transform_doc(&corpus.docs[i]);
        let resp = coord.query_blocking(q).unwrap();
        let truth = corpus.labels[i][0];
        if resp
            .predictions
            .iter()
            .any(|p| trained.label_perm[p.label as usize] == truth)
        {
            hits += 1;
        }
    }
    coord.shutdown();
    assert!(hits > probes / 2, "served recall too low: {hits}/{probes}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn napkinxc_agrees_with_engine_on_trained_model() {
    let spec = CorpusSpec {
        vocab: 800,
        topics: 16,
        docs: 300,
        seed: 9,
        ..Default::default()
    };
    let corpus = Corpus::generate(spec.clone());
    let tfidf = Tfidf::fit(&corpus.docs, spec.vocab);
    let x = tfidf.transform(&corpus.docs);
    let trained = train_model(&x, &corpus.labels, spec.topics, 4, &RankerParams::default(), 2);
    let model = Arc::new(trained.model);
    let ours = InferenceEngine::from_arc(
        Arc::clone(&model),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );
    let napkin = NapkinXcEngine::new(Arc::clone(&model));
    for i in 0..30 {
        let q = tfidf.transform_doc(&corpus.docs[i]);
        assert_eq!(ours.predict(&q, 4, 4), napkin.predict_beam(&q, 4, 4), "doc {i}");
    }
}
