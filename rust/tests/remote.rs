//! Loopback property tests for cross-process shard serving
//! (`mscm_xmr::shard::remote`): remote scatter-gather over 127.0.0.1 is
//! **bitwise identical** to the unsharded engine for S ∈ {1, 2, 4}, both
//! masked-matmul algorithms, `--iter auto` and fixed methods, with and
//! without speculative expansion — and replica failover absorbs a host
//! killed mid-stream with zero failed queries.

#![allow(clippy::type_complexity)]

mod common;

use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    load_shard, partition, save_shards, shard_file_name, RemoteConfig, RemoteCoordinatorConfig,
    RemoteGather, RemoteShardedCoordinator, ShardHost, ShardHostConfig,
};
use mscm_xmr::tree::XmrModel;

fn spec(dim: usize, labels: usize) -> DatasetSpec {
    DatasetSpec {
        name: "remote-prop",
        dim,
        num_labels: labels,
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: 10,
        col_nnz: 6,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

/// Spawns one loopback host per shard of an `s`-way partition; returns
/// the hosts plus their single-replica groups.
fn spawn_hosts(
    model: &XmrModel,
    s: usize,
    cfg: EngineConfig,
) -> (Vec<ShardHost>, Vec<Vec<SocketAddr>>) {
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(model, s) {
        let host = ShardHost::spawn(
            shard,
            ShardHostConfig {
                engine: cfg,
                ..Default::default()
            },
            "127.0.0.1:0",
        )
        .expect("spawn shard host");
        groups.push(vec![host.local_addr()]);
        hosts.push(host);
    }
    (hosts, groups)
}

/// The acceptance property: remote sharded serving over loopback equals
/// the unsharded `InferenceEngine` bit for bit, for S ∈ {1, 2, 4} × both
/// algos × (`--iter auto` + a fixed method) × speculation {off, on}.
#[test]
fn remote_serving_is_bitwise_identical_to_unsharded() {
    let sp = spec(120, 512);
    let model = synth_model(&sp, 8, 0xCAFE);
    let queries = synth_queries(&sp, 6, 0x5EED);
    let configs = [
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Auto),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Hash),
    ];
    for cfg in configs {
        let reference = InferenceEngine::new(model.clone(), cfg);
        for s in [1usize, 2, 4] {
            let (hosts, groups) = spawn_hosts(&model, s, cfg);
            for speculate in [false, true] {
                let mut g = RemoteGather::connect_groups(
                    &groups,
                    RemoteConfig {
                        speculate,
                        ..Default::default()
                    },
                    None,
                )
                .expect("connect remote partition");
                assert_eq!(g.num_shards(), s);
                for qi in 0..queries.rows {
                    let q = queries.row_owned(qi);
                    for beam in [1usize, 3, 10] {
                        let want = reference.predict(&q, beam, 10);
                        let got = g.predict(&q, beam, 10).expect("remote predict");
                        assert_eq!(
                            got,
                            want,
                            "{} S={s} spec={speculate} beam={beam} q={qi}",
                            cfg.label()
                        );
                    }
                }
            }
            for h in hosts {
                h.shutdown();
            }
        }
    }
}

#[test]
fn remote_batch_matches_remote_online() {
    let sp = spec(80, 256);
    let model = synth_model(&sp, 4, 0xBA7C);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let (hosts, groups) = spawn_hosts(&model, 3, cfg);
    let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None).unwrap();
    let x = synth_queries(&sp, 9, 4242);
    g.predict_batch_into(&x, 5, 5).expect("remote batch");
    let batch: Vec<Vec<_>> = g.results().to_vec();
    assert_eq!(batch.len(), 9);
    for (i, want) in batch.iter().enumerate() {
        let got = g.predict(&x.row_owned(i), 5, 5).unwrap();
        assert_eq!(&got, want, "q={i}");
    }
    for h in hosts {
        h.shutdown();
    }
}

/// Shard files written with stored kernel plans serve those plans
/// verbatim when hosted remotely (the `shard --iter auto` → `shard-host`
/// deployment path), staying exact against the unsharded engine.
#[test]
fn shard_files_with_stored_plans_serve_remotely() {
    let sp = spec(80, 256);
    let model = synth_model(&sp, 4, 0x91A7);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let reference = InferenceEngine::new(model.clone(), cfg);
    let mut shards = partition(&model, 3);
    for sh in &mut shards {
        sh.plan_auto(MatmulAlgo::Mscm, &Default::default());
    }
    let dir = mscm_xmr::util::temp_dir("remote-stored-plan");
    save_shards(&shards, &dir).unwrap();
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for id in 0..3u32 {
        let shard = load_shard(shard_file_name(&dir, id, 3), false).unwrap();
        assert!(shard.plan.is_some(), "shard {id} lost its stored plan");
        let host = ShardHost::spawn(
            shard,
            ShardHostConfig {
                engine: cfg,
                ..Default::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        groups.push(vec![host.local_addr()]);
        hosts.push(host);
    }
    let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None).unwrap();
    let queries = synth_queries(&sp, 8, 77);
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(g.predict(&q, 5, 5).unwrap(), reference.predict(&q, 5, 5), "q={qi}");
    }
    for h in hosts {
        h.shutdown();
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Stored **storage layouts** (the `MSCMXMR3` envelope) are honored by
/// remote hosting: shard files whose plans force `DenseRows` / `Merged`
/// weight layouts serve over loopback bitwise identical to the
/// unsharded all-CSC engine — the remote-loopback leg of the
/// layout-exactness property (`tests/layout.rs` covers the in-process
/// legs), driven by the same seeded `tests/common` model generator
/// (`MSCM_TEST_SEED` replayable).
#[test]
fn shard_files_with_stored_layouts_serve_remotely() {
    use mscm_xmr::inference::KernelPlan;
    use mscm_xmr::sparse::ChunkStorage;
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    common::run_cases_capped(3, 80, |_, case| {
        let reference = InferenceEngine::new(
            case.model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        );
        let rows = case.query_rows();
        for storage in [ChunkStorage::DenseRows, ChunkStorage::Merged] {
            let mut shards = partition(&case.model, 2);
            let s_count = shards.len() as u32;
            for sh in &mut shards {
                let plan = KernelPlan::uniform(&sh.model, IterationMethod::BinarySearch)
                    .with_uniform_storage(storage);
                sh.plan = Some((MatmulAlgo::Mscm, plan));
            }
            let dir = mscm_xmr::util::temp_dir(&format!("remote-layouts-{}", storage.short()));
            save_shards(&shards, &dir).unwrap();
            let mut hosts = Vec::new();
            let mut groups = Vec::new();
            for id in 0..s_count {
                let shard = load_shard(shard_file_name(&dir, id, s_count), false).unwrap();
                let (_, plan) = shard.plan.as_ref().expect("stored layout plan");
                assert!(plan.uses_storage(storage), "shard {id} lost its layouts");
                let host = ShardHost::spawn(
                    shard,
                    ShardHostConfig {
                        engine: cfg,
                        ..Default::default()
                    },
                    "127.0.0.1:0",
                )
                .unwrap();
                groups.push(vec![host.local_addr()]);
                hosts.push(host);
            }
            let mut g =
                RemoteGather::connect_groups(&groups, RemoteConfig::default(), None).unwrap();
            for (qi, q) in rows.iter().enumerate() {
                assert_eq!(
                    g.predict(q, 5, 5).unwrap(),
                    reference.predict(q, 5, 5),
                    "{storage:?} q={qi} ({})",
                    case.shape
                );
            }
            for h in hosts {
                h.shutdown();
            }
            std::fs::remove_dir_all(dir).ok();
        }
    });
}

/// Replica failover at the gather level: every shard has two replicas;
/// one replica of shard 0 is killed mid-query-stream and every
/// subsequent query still returns the exact ranking.
#[test]
fn gather_failover_absorbs_a_replica_killed_mid_stream() {
    let sp = spec(80, 256);
    let model = synth_model(&sp, 4, 0xDEAD);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
    let reference = InferenceEngine::new(model.clone(), cfg);
    let shards = partition(&model, 2);
    let host_cfg = ShardHostConfig {
        engine: cfg,
        ..Default::default()
    };
    let mut primaries = Vec::new();
    let mut groups = Vec::new();
    let mut backups = Vec::new();
    for shard in shards {
        let a = ShardHost::spawn(shard.clone(), host_cfg.clone(), "127.0.0.1:0").unwrap();
        let b = ShardHost::spawn(shard, host_cfg.clone(), "127.0.0.1:0").unwrap();
        groups.push(vec![a.local_addr(), b.local_addr()]);
        primaries.push(a);
        backups.push(b);
    }
    let rc = RemoteConfig {
        round_timeout: Duration::from_secs(2),
        ..Default::default()
    };
    let mut g = RemoteGather::connect_groups(&groups, rc, None).unwrap();
    let queries = synth_queries(&sp, 30, 31337);
    for qi in 0..queries.rows {
        if qi == 10 {
            // Sever shard 0's active replica while the stream is live.
            primaries[0].kill();
        }
        let q = queries.row_owned(qi);
        assert_eq!(
            g.predict(&q, 5, 5).expect("query must survive the kill"),
            reference.predict(&q, 5, 5),
            "q={qi}"
        );
    }
    assert!(
        g.stats().failovers.load(Ordering::Relaxed) >= 1,
        "killing the active replica must trigger a failover"
    );
    for h in primaries.into_iter().chain(backups) {
        h.shutdown();
    }
}

/// The acceptance failover property, end to end through the batching
/// coordinator: with 2 replicas per shard, killing one replica mid-batch
/// yields **zero failed queries** and rankings identical to the
/// unsharded engine.
#[test]
fn coordinator_failover_has_zero_failed_queries() {
    let sp = spec(80, 256);
    let model = synth_model(&sp, 4, 0xFA11);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), cfg);
    let host_cfg = ShardHostConfig {
        engine: cfg,
        ..Default::default()
    };
    let mut primaries = Vec::new();
    let mut backups = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        let a = ShardHost::spawn(shard.clone(), host_cfg.clone(), "127.0.0.1:0").unwrap();
        let b = ShardHost::spawn(shard, host_cfg.clone(), "127.0.0.1:0").unwrap();
        groups.push(vec![a.local_addr(), b.local_addr()]);
        primaries.push(a);
        backups.push(b);
    }
    let coord = RemoteShardedCoordinator::start_groups(
        &groups,
        RemoteCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 8,
                max_batch_delay: Duration::from_micros(300),
                beam: 5,
                topk: 5,
                ..Default::default()
            },
            remote: RemoteConfig {
                round_timeout: Duration::from_secs(2),
                ..Default::default()
            },
        },
    )
    .expect("start remote coordinator");
    assert_eq!(coord.num_shards(), 2);

    let queries = synth_queries(&sp, 80, 2718);
    let mut pending = Vec::new();
    for i in 0..40 {
        let q = queries.row_owned(i);
        pending.push((i, coord.submit(q).expect("submit").1));
    }
    // Drain a few replies so batches are demonstrably in flight, then
    // kill shard 0's first replica and keep the stream going.
    for (i, rx) in pending.drain(..10) {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        assert_eq!(resp.predictions, reference.predict(&queries.row_owned(i), 5, 5), "q={i}");
    }
    primaries[0].kill();
    for i in 40..queries.rows {
        let q = queries.row_owned(i);
        pending.push((i, coord.submit(q).expect("submit after kill").1));
    }
    for (i, rx) in pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("query {i} failed after replica kill: {e}"));
        assert_eq!(
            resp.predictions,
            reference.predict(&queries.row_owned(i), 5, 5),
            "q={i}"
        );
    }
    let stats = coord.stats();
    assert_eq!(stats.completed.load(Ordering::Relaxed), 80, "every query must complete");
    let rs = coord.remote_stats();
    assert_eq!(rs.failed_batches.load(Ordering::Relaxed), 0, "no batch may fail");
    assert!(rs.failovers.load(Ordering::Relaxed) >= 1, "the kill must be absorbed by failover");
    // Round telemetry covered every shard.
    assert!(rs.scatter.rounds.load(Ordering::Relaxed) > 0);
    assert!(rs.scatter.shard(0).count() > 0 && rs.scatter.shard(1).count() > 0);
    coord.shutdown();
    for h in primaries.into_iter().chain(backups) {
        h.shutdown();
    }
}

/// A host answers a version-mismatched or malformed handshake with an
/// `Error` frame (so old clients get a diagnosis, not a hang) and closes.
#[test]
fn host_rejects_bad_handshakes_with_error_frames() {
    use mscm_xmr::shard::wire;
    use std::io::Write;

    let sp = spec(64, 81);
    let model = synth_model(&sp, 3, 0xB0B0);
    let (hosts, groups) = spawn_hosts(&model, 1, EngineConfig::default());

    // Wrong protocol version in the Hello header.
    let mut buf = Vec::new();
    wire::encode_hello(&mut buf);
    buf[4..6].copy_from_slice(&(wire::WIRE_VERSION + 7).to_le_bytes());
    let mut stream = std::net::TcpStream::connect(groups[0][0]).unwrap();
    stream.write_all(&buf).unwrap();
    let mut r = std::io::BufReader::new(stream);
    let mut payload = Vec::new();
    assert_eq!(wire::read_frame(&mut r, &mut payload).unwrap(), wire::MsgType::Error);
    let (code, msg) = wire::decode_error(&payload).unwrap();
    assert_eq!(code, wire::ERR_VERSION);
    assert!(msg.contains("version"), "{msg}");

    // A non-Hello first frame is a protocol violation.
    let mut stream = std::net::TcpStream::connect(groups[0][0]).unwrap();
    wire::encode_error(&mut buf, 0, "i speak first");
    stream.write_all(&buf).unwrap();
    let mut r = std::io::BufReader::new(stream);
    assert_eq!(wire::read_frame(&mut r, &mut payload).unwrap(), wire::MsgType::Error);
    let (code, msg) = wire::decode_error(&payload).unwrap();
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(msg.contains("Hello"), "{msg}");

    for h in hosts {
        h.shutdown();
    }
}
