//! Fuzz/robustness tests for the `MSCMXMR3` and `MSCMXMR4` shard
//! envelopes (the `tests/wire.rs` treatment, applied to the on-disk
//! format):
//!
//! - every truncated prefix of a valid V3 or V4 file is rejected,
//! - corrupted magic / plan flags / method codes / storage codes and
//!   trailing garbage are rejected — and, V4-specific: corrupted body
//!   storage tags, a non-1.0 scale on an exact chunk, nonzero
//!   alignment padding and a missing plan tail,
//! - legacy `MSCMXMR2` files still load — plan-less pre-planner files
//!   and method-only plan sections both read as all-`Csc` — and serve
//!   exactly,
//! - save/load round-trips preserve plans for every storage layout and
//!   the loaded shards serve bitwise-identically.
//!
//! The model under test comes from the shared seeded harness
//! (`tests/common`; `MSCM_TEST_SEED` replayable).

mod common;

use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, MatmulAlgo,
};
use mscm_xmr::shard::{
    load_shard, partition, save_shard, save_shard_v4, shard_file_name, ShardedEngine,
};
use mscm_xmr::sparse::ChunkStorage;

/// A deliberately *small* fixed-shape model (the prefix fuzz below is
/// quadratic in the file size) whose shards carry plans exercising
/// every storage code, saved to disk; returns (dir, paths, shards,
/// model). Randomized via the harness base seed.
fn fuzz_model() -> mscm_xmr::XmrModel {
    mscm_xmr::data::synthetic::synth_model(
        &common::dataset_spec("fmt-prop", 24, 18),
        3,
        common::base_seed(),
    )
}

fn fuzz_queries(dim: usize) -> Vec<mscm_xmr::sparse::SparseVec> {
    let mut g = common::ModelGen::new(common::base_seed() ^ 0xF0F0);
    let q = g.queries(dim, 6);
    (0..q.rows).map(|i| q.row_owned(i)).collect()
}

fn saved_partition(
    tag: &str,
) -> (
    std::path::PathBuf,
    Vec<std::path::PathBuf>,
    Vec<mscm_xmr::shard::ShardModel>,
    mscm_xmr::XmrModel,
) {
    let model = fuzz_model();
    let mut shards = partition(&model, 2);
    for sh in &mut shards {
        let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::BinarySearch);
        // Hand-mix the layouts so every storage code appears on disk.
        for l in &mut plan.layers {
            let n = l.storage.len();
            if n >= 2 {
                l.storage[0] = ChunkStorage::Merged;
                l.storage[1] = ChunkStorage::Merged;
            }
            if n >= 1 {
                l.storage[n - 1] = ChunkStorage::DenseRows;
            }
        }
        sh.plan = Some((MatmulAlgo::Mscm, plan));
    }
    let dir = mscm_xmr::util::temp_dir(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let mut paths = Vec::new();
    for sh in &shards {
        let p = shard_file_name(&dir, sh.spec.shard_id, sh.spec.num_shards);
        save_shard(sh, &p).unwrap();
        paths.push(p);
    }
    (dir, paths, shards, model)
}

#[test]
fn every_truncated_prefix_is_rejected() {
    let (dir, paths, _, _) = saved_partition("fmt-prefix");
    let bytes = std::fs::read(&paths[0]).unwrap();
    let scratch = dir.join("prefix.bin");
    // The full file parses; every strict prefix must be rejected (a V3
    // file has no optional tail — even the plan flag is mandatory).
    assert!(load_shard(&paths[0], false).is_ok());
    for len in 0..bytes.len() {
        std::fs::write(&scratch, &bytes[..len]).unwrap();
        assert!(
            load_shard(&scratch, false).is_err(),
            "prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupted_tags_and_versions_are_rejected() {
    let (dir, paths, shards, _) = saved_partition("fmt-corrupt");
    let bytes = std::fs::read(&paths[0]).unwrap();
    let scratch = dir.join("corrupt.bin");
    let check_err = |mutated: Vec<u8>, what: &str| {
        std::fs::write(&scratch, &mutated).unwrap();
        assert!(load_shard(&scratch, false).is_err(), "{what} accepted");
    };

    // Unknown future version and the raw model magic are both rejected.
    // (`…MXR4` is a real format now — version fuzzing moved to 0x35.)
    let mut v5 = bytes.clone();
    v5[0] = 0x35; // "…MXR5"
    check_err(v5, "future version magic");
    let mut v1 = bytes.clone();
    v1[0] = 0x31; // the MSCMXMR1 model magic
    check_err(v1, "model-file magic");

    // Trailing garbage after a complete V3 payload.
    let mut padded = bytes.clone();
    padded.push(0xAB);
    check_err(padded, "trailing byte");

    // The last 4 bytes are the bottom layer's final storage code; an
    // unknown layout tag must be rejected.
    let mut bad_storage = bytes.clone();
    let n = bad_storage.len();
    bad_storage[n - 4] = 0xEE;
    check_err(bad_storage, "unknown storage code");

    // ... and an unknown method code likewise. The bottom layer's plan
    // row is `count u64 | methods | storages`, so the first method code
    // sits 8 * num_chunks before the storage codes.
    let chunks_bottom = shards[0]
        .model
        .layers
        .last()
        .unwrap()
        .chunked
        .num_chunks();
    let mut bad_method = bytes.clone();
    let mpos = n - 8 * chunks_bottom;
    bad_method[mpos] = 0xC8;
    check_err(bad_method, "unknown method code");

    // A nonsense plan-presence flag. The flag sits right before the
    // first layer's plan row; locate it by re-encoding the plan section
    // length: total plan bytes = 8 (flag) + per layer (8 + 8n).
    let plan_bytes: usize = 8
        + shards[0]
            .model
            .layers
            .iter()
            .map(|l| 8 + 8 * l.chunked.num_chunks())
            .sum::<usize>();
    let mut bad_flag = bytes.clone();
    bad_flag[n - plan_bytes] = 9;
    check_err(bad_flag, "bad plan flag");

    std::fs::remove_dir_all(dir).ok();
}

/// Rewrites a V3 file's bytes as the legacy V2 layout: magic patched
/// down, and the plan section re-encoded without storage codes (or
/// dropped entirely for the pre-planner shape).
fn as_v2(bytes: &[u8], shard: &mscm_xmr::shard::ShardModel, with_plan: bool) -> Vec<u8> {
    let plan_bytes: usize = 8
        + shard
            .model
            .layers
            .iter()
            .map(|l| 8 + 8 * l.chunked.num_chunks())
            .sum::<usize>();
    let mut out = bytes[..bytes.len() - plan_bytes].to_vec();
    out[0] = 0x32; // "…MXR3" -> "…MXR2"
    if with_plan {
        let (algo, plan) = shard.plan.as_ref().unwrap();
        out.extend_from_slice(
            &(match algo {
                MatmulAlgo::Mscm => 1u64,
                MatmulAlgo::Baseline => 2u64,
            })
            .to_le_bytes(),
        );
        for l in &plan.layers {
            out.extend_from_slice(&(l.methods.len() as u64).to_le_bytes());
            for m in &l.methods {
                out.extend_from_slice(&(m.index() as u32).to_le_bytes());
            }
        }
    }
    out
}

#[test]
fn legacy_v2_files_load_as_csc_and_serve_exactly() {
    let (dir, paths, shards, model) = saved_partition("fmt-v2");
    let mut loaded = Vec::new();
    for (path, shard) in paths.iter().zip(&shards) {
        let bytes = std::fs::read(path).unwrap();

        // Pre-planner V2: ends at the model body; loads plan-less.
        let v2_path = dir.join("v2.bin");
        std::fs::write(&v2_path, as_v2(&bytes, shard, false)).unwrap();
        let preplanner = load_shard(&v2_path, false).unwrap();
        assert!(preplanner.plan.is_none());
        assert_eq!(preplanner.spec, shard.spec);

        // Planned V2: method codes only; every chunk reads as Csc.
        std::fs::write(&v2_path, as_v2(&bytes, shard, true)).unwrap();
        let planned = load_shard(&v2_path, false).unwrap();
        let (algo, plan) = planned.plan.as_ref().expect("stored V2 plan");
        assert_eq!(*algo, MatmulAlgo::Mscm);
        assert_eq!(
            plan.layers.iter().map(|l| l.methods.clone()).collect::<Vec<_>>(),
            shard
                .plan
                .as_ref()
                .unwrap()
                .1
                .layers
                .iter()
                .map(|l| l.methods.clone())
                .collect::<Vec<_>>()
        );
        assert!(
            !plan.uses_storage(ChunkStorage::DenseRows)
                && !plan.uses_storage(ChunkStorage::Merged),
            "V2 plans must read as all-Csc"
        );
        loaded.push(planned);
    }
    // The V2-loaded partition still serves bitwise-identically.
    let reference = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
    );
    let sharded = ShardedEngine::new(
        loaded,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
    );
    for (qi, q) in fuzz_queries(reference.model().dim).iter().enumerate() {
        assert_eq!(
            sharded.predict(q, 4, 5),
            reference.predict(q, 4, 5),
            "q={qi}"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Saves the fuzz model as one `MSCMXMR4` shard whose hand-mixed plan
/// exercises every storage code — the quantized pair included — and
/// returns (dir, path, loaded shard). The loaded copy supplies the
/// layer shapes the offset arithmetic below needs.
fn saved_v4(tag: &str) -> (
    std::path::PathBuf,
    std::path::PathBuf,
    mscm_xmr::shard::ShardModel,
) {
    let model = fuzz_model();
    // One shard keeps every chunk, maximizing per-layer chunk counts.
    let mut sh = partition(&model, 1).remove(0);
    let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::BinarySearch);
    for l in &mut plan.layers {
        let n = l.storage.len();
        if n >= 2 {
            l.storage[0] = ChunkStorage::F16;
        }
        if n >= 3 {
            l.storage[1] = ChunkStorage::Int8;
        }
        if n >= 5 {
            l.storage[2] = ChunkStorage::Merged;
            l.storage[3] = ChunkStorage::Merged;
        }
        l.storage[n - 1] = ChunkStorage::DenseRows;
    }
    assert!(
        plan.uses_storage(ChunkStorage::F16) && plan.uses_storage(ChunkStorage::Int8),
        "fuzz model too narrow to place the quantized layouts"
    );
    sh.plan = Some((MatmulAlgo::Mscm, plan));
    let dir = mscm_xmr::util::temp_dir(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("shard.v4.bin");
    save_shard_v4(&sh, &path).unwrap();
    let loaded = load_shard(&path, false).unwrap();
    assert_eq!(loaded.spec, sh.spec);
    assert_eq!(loaded.plan, sh.plan, "V4 plan round-trips");
    (dir, path, loaded)
}

#[test]
fn v4_every_truncated_prefix_is_rejected() {
    let (dir, path, _) = saved_v4("fmt-v4-prefix");
    let bytes = std::fs::read(&path).unwrap();
    let scratch = dir.join("prefix.bin");
    for len in 0..bytes.len() {
        std::fs::write(&scratch, &bytes[..len]).unwrap();
        assert!(
            load_shard(&scratch, false).is_err(),
            "V4 prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn v4_corrupted_fields_are_rejected() {
    let (dir, path, shard) = saved_v4("fmt-v4-corrupt");
    let bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    let scratch = dir.join("corrupt.bin");
    let check_err = |mutated: Vec<u8>, what: &str| {
        std::fs::write(&scratch, &mutated).unwrap();
        assert!(load_shard(&scratch, false).is_err(), "{what} accepted");
    };

    // Unknown future version on a V4 image, and trailing garbage (the
    // V4 tail is strict — nothing may follow the plan rows).
    let mut v5 = bytes.clone();
    v5[0] = 0x35;
    check_err(v5, "future version magic");
    let mut padded = bytes.clone();
    padded.push(0xAB);
    check_err(padded, "trailing byte");

    // The plan tail reuses the V3 row encoding, so the V3 offsets hold:
    // storage codes end the file, method codes sit 8 * num_chunks
    // before them, and the algo flag leads the section.
    let chunks_bottom = shard.model.layers.last().unwrap().chunked.num_chunks();
    let mut bad_storage = bytes.clone();
    bad_storage[n - 4] = 0xEE;
    check_err(bad_storage, "unknown plan storage code");
    let mut bad_method = bytes.clone();
    bad_method[n - 8 * chunks_bottom] = 0xC8;
    check_err(bad_method, "unknown plan method code");
    let plan_bytes: usize = 8
        + shard
            .model
            .layers
            .iter()
            .map(|l| 8 + 8 * l.chunked.num_chunks())
            .sum::<usize>();
    let mut bad_flag = bytes.clone();
    bad_flag[n - plan_bytes] = 9;
    check_err(bad_flag, "bad plan flag");
    // Flag 0 (plan-less) is legal V3 but not V4: a layout-resolved
    // shard without its plan cannot be served.
    let mut no_plan = bytes[..n - plan_bytes].to_vec();
    no_plan.extend_from_slice(&0u64.to_le_bytes());
    check_err(no_plan, "plan-less V4");

    // Body offsets, from the front: magic (8) + spec header
    // (7 u64 + layer_offsets u32s) + dim u64 + layer 0's cols +
    // num_chunks u64s + (nc0 + 1) chunk offsets lands on chunk 0's
    // storage tag; scale sits 12 bytes further (after the three u32s);
    // the chunk header is 56 bytes, and the first weight array is
    // 64-byte aligned right after it.
    let nc0 = shard.model.layers[0].chunked.num_chunks();
    let body = 8 + 56 + 4 * shard.layer_offsets.len() + 8 + 16 + 4 * (nc0 + 1);
    let mut bad_tag = bytes.clone();
    bad_tag[body] = 0xEE;
    check_err(bad_tag, "unknown body storage tag");
    // Chunk 0 of layer 0 is exact (DenseRows), so its scale must be
    // exactly 1.0 on disk.
    let mut bad_scale = bytes.clone();
    assert_eq!(&bad_scale[body + 12..body + 16], &1.0f32.to_le_bytes());
    bad_scale[body + 12] ^= 0x01;
    check_err(bad_scale, "non-1.0 scale on an exact chunk");
    // Alignment padding must be zero.
    let pad_at = body + 56;
    assert!(pad_at % 64 != 0, "fuzz shape leaves no padding to corrupt");
    let mut bad_pad = bytes.clone();
    bad_pad[pad_at] = 0x5A;
    check_err(bad_pad, "nonzero alignment padding");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn round_trip_preserves_every_layout_and_serves() {
    let (dir, paths, shards, model) = saved_partition("fmt-roundtrip");
    let mut loaded = Vec::new();
    for (path, shard) in paths.iter().zip(&shards) {
        let l = load_shard(path, false).unwrap();
        assert_eq!(l.spec, shard.spec);
        assert_eq!(l.plan, shard.plan, "plan (layouts included) round-trips");
        loaded.push(l);
    }
    let reference = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
    );
    let sharded = ShardedEngine::new(
        loaded,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto),
    );
    for (qi, q) in fuzz_queries(reference.model().dim).iter().enumerate() {
        assert_eq!(
            sharded.predict(q, 4, 5),
            reference.predict(q, 4, 5),
            "q={qi}"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}
