//! SIMD-tier exactness properties: the vectorized kernels are **bitwise
//! identical** to their scalar oracles on arbitrary models and queries —
//! the invariant that lets the planner flip tiers per chunk without
//! changing a single prediction.
//!
//! Two levels of attack, both over the shared seeded harness in
//! `tests/common` (`MSCM_TEST_SEED` replays failures):
//!
//! - **kernel level**: every `vec_chunk_*_simd` against its scalar
//!   oracle, chunk by chunk, across all storage layouts — random widths
//!   and row counts exercise every remainder-lane shape around the 8-wide
//!   gathers and 4/8-wide accumulate runs;
//! - **engine level**: whole engines with the plan tier forced to SIMD
//!   against forced-scalar twins, both algorithms, online and batch,
//!   beams 1 and 4.
//!
//! On hardware without a vector unit (or under `MSCM_FORCE_SCALAR=1` —
//! a dedicated CI leg) the SIMD tier degrades to the scalar kernels, so
//! every assertion here becomes `scalar == scalar`: the suite is green
//! everywhere, and only *proves* vectorization correct where it runs.

mod common;

use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, KernelTier, MatmulAlgo,
    PlannerConfig,
};
use mscm_xmr::sparse::iterators::{
    vec_chunk_binary, vec_chunk_binary_simd, vec_chunk_dense, vec_chunk_dense_rows,
    vec_chunk_dense_rows_simd, vec_chunk_dense_simd, vec_chunk_hash, vec_chunk_hash_simd,
    vec_chunk_marching, vec_chunk_marching_simd, DenseScratch,
};
use mscm_xmr::sparse::{ChunkStorage, ChunkedMatrix, SimdLevel};

/// Every tiered kernel pair on every chunk of a random matrix under one
/// storage layout. `out` pairs are compared with `==` — bitwise, since
/// equal f32 bit patterns are the only way NaN-free equal floats arise
/// from these loops.
fn check_layout(
    chunked: &ChunkedMatrix,
    queries: &mscm_xmr::sparse::CsrMatrix,
    scratch: &mut DenseScratch,
    level: SimdLevel,
    ctx: &str,
) {
    for c in 0..chunked.num_chunks() {
        let cv = chunked.view(c);
        let w = cv.ncols as usize;
        let mut a = vec![0.0f32; w];
        let mut b = vec![0.0f32; w];
        for qi in 0..queries.rows {
            let x = queries.row(qi);
            let mut run = |scalar: &mut dyn FnMut(&mut [f32]),
                           simd: &mut dyn FnMut(&mut [f32]),
                           kernel: &str| {
                a.fill(0.0);
                b.fill(0.0);
                scalar(&mut a);
                simd(&mut b);
                assert_eq!(a, b, "{kernel} diverged on chunk {c} q {qi} ({ctx})");
            };
            match cv.storage {
                ChunkStorage::DenseRows => {
                    run(
                        &mut |o| vec_chunk_dense_rows(x, cv, o),
                        &mut |o| vec_chunk_dense_rows_simd(x, cv, o, level),
                        "dense-rows",
                    );
                }
                storage => {
                    run(
                        &mut |o| vec_chunk_marching(x, cv, o),
                        &mut |o| vec_chunk_marching_simd(x, cv, o, level),
                        "marching",
                    );
                    run(
                        &mut |o| vec_chunk_binary(x, cv, o),
                        &mut |o| vec_chunk_binary_simd(x, cv, o, level),
                        "binary",
                    );
                    if storage == ChunkStorage::Csc && cv.row_map.is_some() {
                        run(
                            &mut |o| vec_chunk_hash(x, cv, o),
                            &mut |o| vec_chunk_hash_simd(x, cv, o, level),
                            "hash",
                        );
                    }
                    scratch.load(cv);
                    {
                        let s: &DenseScratch = scratch;
                        run(
                            &mut |o| vec_chunk_dense(x, cv, s, o),
                            &mut |o| vec_chunk_dense_simd(x, cv, s, o, level),
                            "dense",
                        );
                    }
                    scratch.clear(cv);
                }
            }
        }
    }
}

#[test]
fn simd_kernels_match_scalar_oracles_on_random_chunks() {
    let level = SimdLevel::detect();
    let base = common::base_seed();
    let mut g = common::ModelGen::new(base ^ 0x51D0);
    for case in 0..40 {
        let (csc, offsets) = g.matrix();
        let queries = g.queries(csc.rows, 4);
        let mut scratch = DenseScratch::new(csc.rows);
        let seed = ChunkedMatrix::from_csc(&csc, &offsets, true);
        let n = seed.num_chunks();
        for storage in ChunkStorage::ALL {
            let mut chunked = seed.clone();
            chunked.apply_layout(&vec![storage; n]);
            let ctx = format!("case {case} {storage:?} seed base {base:#x}");
            check_layout(&chunked, &queries, &mut scratch, level, &ctx);
        }
        // Mixed layouts, the shape real plans produce.
        let mut chunked = seed.clone();
        let layout: Vec<ChunkStorage> = (0..n).map(|_| ChunkStorage::ALL[g.pick(0..3)]).collect();
        chunked.apply_layout(&layout);
        let ctx = format!("case {case} mixed seed base {base:#x}");
        check_layout(&chunked, &queries, &mut scratch, level, &ctx);
    }
}

/// A `(model, config, uniform method, tier)` engine: the plan is the
/// uniform method plan with every block pinned to `tier`.
fn tiered_engine(
    case: &common::GenCase,
    algo: MatmulAlgo,
    iter: IterationMethod,
    tier: KernelTier,
) -> InferenceEngine {
    let mut m = case.model.clone();
    m.build_row_maps();
    let plan = KernelPlan::uniform(&m, iter).with_uniform_tier(tier);
    InferenceEngine::new_with_plan(m, EngineConfig::new(algo, iter), plan)
}

#[test]
fn forced_simd_engines_match_scalar_twins() {
    common::run_cases_capped(10, 200, |case_id, case| {
        let rows = case.query_rows();
        for algo in MatmulAlgo::ALL {
            // One method per case keeps the grid affordable; across the
            // ten cases all four methods recur for both algorithms.
            let iter = IterationMethod::ALL[(case_id as usize + algo as usize) % 4];
            let scalar = tiered_engine(case, algo, iter, KernelTier::Scalar);
            let simd = tiered_engine(case, algo, iter, KernelTier::Simd);
            for beam in [1usize, 4] {
                let want = scalar.predict_batch(&case.queries, beam, 5);
                let got = simd.predict_batch(&case.queries, beam, 5);
                assert_eq!(
                    got, want,
                    "batch {algo:?}/{iter:?} beam={beam} ({})",
                    case.shape
                );
                for (qi, row) in rows.iter().enumerate() {
                    assert_eq!(
                        simd.predict(row, beam, 5),
                        scalar.predict(row, beam, 5),
                        "online {algo:?}/{iter:?} beam={beam} q={qi} ({})",
                        case.shape
                    );
                }
            }
        }
    });
}

#[test]
fn auto_plan_matches_its_scalar_tier_twin() {
    common::run_cases_capped(10, 200, |_, case| {
        let mut m = case.model.clone();
        m.build_row_maps();
        let pc = PlannerConfig::default();
        let plan = KernelPlan::auto(&m, MatmulAlgo::Mscm, &pc);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
        let scalar_plan = plan.clone().with_uniform_tier(KernelTier::Scalar);
        let auto = InferenceEngine::new_with_plan(m.clone(), cfg, plan);
        let scalar = InferenceEngine::new_with_plan(m, cfg, scalar_plan);
        for beam in [1usize, 4] {
            assert_eq!(
                auto.predict_batch(&case.queries, beam, 5),
                scalar.predict_batch(&case.queries, beam, 5),
                "auto-plan tier divergence beam={beam} ({})",
                case.shape
            );
        }
    });
}

#[test]
fn forced_simd_parallel_batches_match_serial_scalar() {
    common::run_cases_capped(6, 200, |_, case| {
        let scalar = tiered_engine(
            case,
            MatmulAlgo::Mscm,
            IterationMethod::MarchingPointers,
            KernelTier::Scalar,
        );
        let simd = tiered_engine(
            case,
            MatmulAlgo::Mscm,
            IterationMethod::MarchingPointers,
            KernelTier::Simd,
        );
        let want = scalar.predict_batch(&case.queries, 4, 4);
        for threads in [2usize, 5] {
            assert_eq!(
                simd.predict_batch_parallel(&case.queries, 4, 4, threads),
                want,
                "t={threads} ({})",
                case.shape
            );
        }
    });
}
