//! Randomized property tests for the substrate layers (hand-rolled
//! seeded-case harness; proptest is unavailable offline).

use std::collections::HashMap;

use mscm_xmr::metrics::LatencyHistogram;
use mscm_xmr::sparse::{CsrMatrix, SparseVec, U32Map};
use mscm_xmr::util::{Json, Rng};

#[test]
fn u32map_behaves_like_std_hashmap() {
    let mut rng = Rng::seed_from_u64(1);
    for _case in 0..30 {
        let n = rng.gen_range(0..400);
        let mut ours = U32Map::with_capacity(n);
        let mut std_map: HashMap<u32, u32> = HashMap::new();
        for _ in 0..n {
            let k = rng.gen_range(0..300) as u32; // collisions likely
            let v = rng.next_u64() as u32;
            ours.insert(k, v);
            std_map.insert(k, v);
        }
        assert_eq!(ours.len(), std_map.len());
        for k in 0..300u32 {
            assert_eq!(ours.get(k), std_map.get(&k).copied(), "key {k}");
        }
        let mut a: Vec<_> = ours.iter().collect();
        let mut b: Vec<_> = std_map.into_iter().collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.gen_range(0..4) } else { rng.gen_range(0..6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.gen_bool(0.5)),
        2 => {
            // integral and fractional values (round-trippable f64s)
            if rng.gen_bool(0.5) {
                Json::Num(rng.gen_range(0..1_000_000) as f64 - 500_000.0)
            } else {
                Json::Num((rng.gen_range(0..1000) as f64) / 8.0)
            }
        }
        3 => {
            let len = rng.gen_range(0..12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.gen_range(0..5);
                    match c {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'é',
                        _ => (b'a' + rng.gen_range(0..26) as u8) as char,
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.gen_range(0..5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.gen_range(0..5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn json_round_trips_random_values() {
    let mut rng = Rng::seed_from_u64(2);
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("case {case}: {e} on {s}"));
        assert_eq!(back, v, "case {case}: {s}");
    }
}

#[test]
fn csr_csc_round_trip_preserves_dense() {
    let mut rng = Rng::seed_from_u64(3);
    for _ in 0..40 {
        let (r, c) = (rng.gen_range(1..30), rng.gen_range(1..30));
        let rows: Vec<SparseVec> = (0..r)
            .map(|_| {
                SparseVec::from_pairs(
                    (0..rng.gen_range(0..c + 1))
                        .map(|_| (rng.gen_range(0..c) as u32, rng.gen_f32(-3.0, 3.0)))
                        .collect(),
                )
            })
            .collect();
        let m = CsrMatrix::from_rows(rows, c);
        let csc = m.to_csc();
        for i in 0..r {
            for (&j, &v) in m.row(i).indices.iter().zip(m.row(i).values) {
                let col = csc.col(j as usize);
                let pos = col.indices.binary_search(&(i as u32)).expect("entry");
                assert_eq!(col.values[pos], v);
            }
        }
        assert_eq!(m.nnz(), csc.nnz());
    }
}

#[test]
fn model_save_load_identity_random() {
    let mut rng = Rng::seed_from_u64(4);
    let dir = mscm_xmr::util::temp_dir("props");
    for case in 0..6 {
        let spec = mscm_xmr::data::synthetic::DatasetSpec {
            name: "props",
            dim: rng.gen_range(8..200),
            num_labels: rng.gen_range(2..80),
            paper_dim: 0,
            paper_labels: 0,
            query_nnz: 5,
            col_nnz: rng.gen_range(1..10),
            sibling_overlap: rng.gen_f64(),
            zipf_theta: 1.0,
        };
        let model = mscm_xmr::data::synthetic::synth_model(&spec, 2 + case % 5, case as u64);
        let path = dir.join(format!("m{case}.bin"));
        mscm_xmr::tree::save_model(&model, &path).unwrap();
        let loaded = mscm_xmr::tree::load_model(&path, false).unwrap();
        assert_eq!(loaded.dim, model.dim);
        for (a, b) in model.layers.iter().zip(&loaded.layers) {
            assert_eq!(a.csc, b.csc);
            assert_eq!(a.chunked.chunk_offsets, b.chunked.chunk_offsets);
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn histogram_quantiles_bounded_and_monotone() {
    let mut rng = Rng::seed_from_u64(5);
    for _ in 0..10 {
        let h = LatencyHistogram::new();
        let n = rng.gen_range(1..2000);
        let mut max_us = 0u64;
        for _ in 0..n {
            let us = rng.gen_range(1..2_000_000) as u64;
            max_us = max_us.max(us);
            h.record(std::time::Duration::from_micros(us));
        }
        let mut last = 0.0;
        for q in [0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let v = h.quantile_ms(q);
            assert!(v >= last, "quantiles must be monotone");
            last = v;
        }
        // bucket upper bound can exceed the true max by one bucket width (≤25%)
        assert!(h.quantile_ms(1.0) <= (max_us as f64 / 1e3) * 1.3 + 0.002);
        assert!(h.mean_ms() <= max_us as f64 / 1e3);
    }
}

#[test]
fn sparsevec_axpy_matches_dense() {
    let mut rng = Rng::seed_from_u64(6);
    for _ in 0..50 {
        let d = rng.gen_range(1..40);
        let mk = |rng: &mut Rng| {
            SparseVec::from_pairs(
                (0..rng.gen_range(0..d + 1))
                    .map(|_| (rng.gen_range(0..d) as u32, rng.gen_f32(-2.0, 2.0)))
                    .collect(),
            )
        };
        let mut a = mk(&mut rng);
        let b = mk(&mut rng);
        let alpha = rng.gen_f32(-2.0, 2.0);
        let mut dense = a.view().to_dense(d);
        for (i, v) in dense.iter_mut().enumerate() {
            if let Ok(p) = b.indices.binary_search(&(i as u32)) {
                *v += alpha * b.values[p];
            }
        }
        a.axpy(alpha, b.view());
        assert_eq!(a.view().to_dense(d), dense);
        // support stays sorted + unique
        assert!(a.indices.windows(2).all(|w| w[0] < w[1]));
    }
}
