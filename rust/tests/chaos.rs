//! Chaos suite for the cross-process serving stack
//! (`mscm_xmr::shard::{fault, remote}`): seeded, replayable fault
//! injection against live loopback shard hosts. The properties pinned
//! here are the transport's robustness contract:
//!
//! - every **non-degraded** response is bitwise identical to the
//!   unsharded oracle, no matter which faults fired;
//! - no batch outlives its deadline budget;
//! - a replica that dies is ejected by the circuit breaker and, once
//!   restarted on the same address, rejoins and serves again;
//! - `allow_partial` flags exactly the down shards and degrades to the
//!   live shards' exact sub-ranking, while the default mode stays
//!   exact-or-fail (and two-replica failover still loses zero queries);
//! - slow-loris and paused ("dead-but-connected") hosts are absorbed by
//!   timeouts/hedging, never decoded into garbage.
//!
//! All fault schedules derive from `MSCM_TEST_SEED` (see
//! `tests/common`), so a CI failure replays exactly.

mod common;

use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    partition, poll_stats, FaultPlan, RemoteConfig, RemoteCoordinatorConfig, RemoteGather,
    RemoteShardedCoordinator, ReplicaPhase, ShardHost, ShardHostConfig,
};
use mscm_xmr::tree::XmrModel;

fn spec(dim: usize, labels: usize) -> DatasetSpec {
    DatasetSpec {
        name: "chaos-prop",
        dim,
        num_labels: labels,
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: 10,
        col_nnz: 6,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

fn host_cfg(engine: EngineConfig) -> ShardHostConfig {
    ShardHostConfig {
        engine,
        ..Default::default()
    }
}

/// Spawns a faulty primary + healthy backup per shard; returns
/// `(primaries, backups, groups)`.
fn spawn_faulty_partition(
    model: &XmrModel,
    s: usize,
    engine: EngineConfig,
    plan: &FaultPlan,
) -> (Vec<ShardHost>, Vec<ShardHost>, Vec<Vec<SocketAddr>>) {
    let mut primaries = Vec::new();
    let mut backups = Vec::new();
    let mut groups = Vec::new();
    for (i, shard) in partition(model, s).into_iter().enumerate() {
        let mut plan = plan.clone();
        plan.seed ^= (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let a = ShardHost::with_faults(shard.clone(), host_cfg(engine), "127.0.0.1:0", plan)
            .expect("spawn faulty host");
        let b = ShardHost::spawn(shard, host_cfg(engine), "127.0.0.1:0").expect("spawn backup");
        groups.push(vec![a.local_addr(), b.local_addr()]);
        primaries.push(a);
        backups.push(b);
    }
    (primaries, backups, groups)
}

/// Tentpole exactness property: with one replica per shard running a
/// hostile fault schedule (dropped, delayed, corrupted and truncated
/// replies) and a healthy backup, every query over the chaotic stream
/// returns the oracle ranking bit for bit — corruption is always
/// detected (header-only injection; see `shard::fault` docs), never
/// decoded into a wrong answer.
#[test]
fn faulty_replicas_never_break_bitwise_exactness() {
    let sp = spec(96, 256);
    let model = synth_model(&sp, 5, 0xC4A0);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), engine);
    let plan = FaultPlan {
        seed: common::base_seed(),
        drop_after_frames: Some(3),
        delay_replies: Duration::from_millis(2),
        corrupt_frame: 0.5,
        truncate_frame: 0.4,
        ..Default::default()
    };
    let (primaries, backups, groups) = spawn_faulty_partition(&model, 2, engine, &plan);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_secs(2),
            ..Default::default()
        },
        None,
    )
    .expect("connect through the faulty partition");
    let queries = synth_queries(&sp, 30, 0xFEED);
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(
            g.predict(&q, 5, 5).expect("query must survive the fault schedule"),
            reference.predict(&q, 5, 5),
            "q={qi} (replay with MSCM_TEST_SEED={})",
            common::base_seed()
        );
    }
    assert!(
        g.stats().failovers.load(Ordering::Relaxed) >= 1,
        "a drop-after-3-frames schedule must force failovers"
    );
    for h in primaries.into_iter().chain(backups) {
        h.shutdown();
    }
}

/// Deadline budgets: a paused host (socket open, no bytes ever coming
/// back — the shape a plain connection error never produces) must fail
/// the batch within the budget, not hang for the full round timeout.
/// After `resume`, the very next query is exact again.
#[test]
fn deadline_bounds_batches_against_a_paused_host() {
    let sp = spec(64, 128);
    let model = synth_model(&sp, 4, 0xDEAD);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
    let reference = InferenceEngine::new(model.clone(), engine);
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        // Default plan = no faults; spawning through `with_faults` is
        // what installs the pause/resume latch.
        let h = ShardHost::with_faults(shard, host_cfg(engine), "127.0.0.1:0", FaultPlan::default())
            .unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let deadline = Duration::from_millis(300);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            // The round timeout is deliberately far larger than the
            // deadline: only the budget can be what bounds the batch.
            round_timeout: Duration::from_secs(30),
            deadline,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 4, 0x0B5E);
    let q0 = queries.row_owned(0);
    assert_eq!(g.predict(&q0, 5, 5).unwrap(), reference.predict(&q0, 5, 5));

    hosts[0].pause();
    let t0 = Instant::now();
    let err = g.predict(&q0, 5, 5).expect_err("a paused shard must fail the batch");
    let elapsed = t0.elapsed();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(
        elapsed < deadline * 8,
        "batch outlived its deadline: {elapsed:?} vs budget {deadline:?}"
    );

    hosts[0].resume();
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(
            g.predict(&q, 5, 5).expect("resumed host must serve again"),
            reference.predict(&q, 5, 5),
            "q={qi} after resume"
        );
    }
    for h in hosts {
        h.shutdown();
    }
}

/// Regression pin for the zero-deadline sentinel collision: a budget
/// that expires while a paused host holds the read (and one that
/// expires *between* rounds behind delayed replies) must fail the batch
/// with `TimedOut` — and must **not** be booked as replica failures.
/// Pre-fix, budget expiry ran the failover path: `failovers` was bumped
/// per expired batch and, past `eject_after` of them, the perfectly
/// healthy replica was ejected by the circuit breaker.
#[test]
fn deadline_expiry_never_penalizes_healthy_replicas() {
    let sp = spec(64, 128);
    let model = synth_model(&sp, 4, 0xB4D6);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), engine);
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        let h = ShardHost::with_faults(shard, host_cfg(engine), "127.0.0.1:0", FaultPlan::default())
            .unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let deadline = Duration::from_millis(200);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            // Only the deadline budget can bound these batches; the
            // round timeout would allow a 30 s stall.
            round_timeout: Duration::from_secs(30),
            deadline,
            eject_after: 3,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 4, 0xB4D7);
    let q0 = queries.row_owned(0);
    assert_eq!(g.predict(&q0, 5, 5).unwrap(), reference.predict(&q0, 5, 5));

    // More expired batches than `eject_after`: pre-fix this ejects the
    // replica; post-fix it must not even count as a failover.
    hosts[0].pause();
    let t0 = Instant::now();
    for i in 0..4 {
        let err = g
            .predict(&q0, 5, 5)
            .expect_err("an expired budget must fail the batch");
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "batch {i}: {err}");
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < deadline * 4 * 8,
        "expired batches outlived their budgets: {elapsed:?} vs 4 x {deadline:?}"
    );
    assert_eq!(
        g.stats().failovers.load(Ordering::Relaxed),
        0,
        "budget expiry must not be booked as a replica failover"
    );
    assert_eq!(
        g.stats().ejections.load(Ordering::Relaxed),
        0,
        "budget expiry must not eject a healthy replica"
    );

    // The replica was never poisoned: the very next query after resume
    // is exact, with no cooldown to wait out.
    hosts[0].resume();
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(
            g.predict(&q, 5, 5).expect("unpenalized replica must serve immediately"),
            reference.predict(&q, 5, 5),
            "q={qi} after resume"
        );
    }
    drop(g);
    for h in hosts {
        h.shutdown();
    }

    // Between-rounds expiry: every reply is delayed by more than half
    // the budget, so the second round's budget has already lapsed when
    // (or shortly after) it starts. Still `TimedOut`, still zero
    // failovers.
    let delay = FaultPlan {
        seed: common::base_seed() ^ 2,
        delay_replies: Duration::from_millis(200),
        ..Default::default()
    };
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        let h = ShardHost::with_faults(shard, host_cfg(engine), "127.0.0.1:0", delay.clone())
            .unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_secs(30),
            deadline: Duration::from_millis(300),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let err = g
        .predict(&q0, 5, 5)
        .expect_err("a budget lapsing between rounds must fail the batch");
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert_eq!(
        g.stats().failovers.load(Ordering::Relaxed),
        0,
        "between-rounds expiry must not be booked as a failover"
    );
    for h in hosts {
        h.shutdown();
    }
}

/// Degraded mode: killing every replica of shard 1 fails the default
/// (exact-or-fail) gather but lets an `allow_partial` gather answer from
/// shard 0 alone — flagged, counted, and bitwise equal to serving shard
/// 0's sub-model by itself.
#[test]
fn allow_partial_flags_exactly_the_down_shards() {
    let sp = spec(96, 256);
    let model = synth_model(&sp, 5, 0x9A57);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), engine);
    let shards = partition(&model, 2);
    // Shard 0's model is self-contained over label range [0, cut), so
    // the degraded oracle is just that sub-model served alone.
    let sub_oracle = InferenceEngine::new(shards[0].model.clone(), engine);
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in shards {
        let h = ShardHost::spawn(shard, host_cfg(engine), "127.0.0.1:0").unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let rc = RemoteConfig {
        round_timeout: Duration::from_millis(500),
        ..Default::default()
    };
    let mut g_default = RemoteGather::connect_groups(&groups, rc.clone(), None).unwrap();
    let mut g_partial = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            allow_partial: true,
            ..rc
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 8, 0x1DEA);
    // Full fidelity while everything is up: no degraded flag.
    let q0 = queries.row_owned(0);
    assert_eq!(g_partial.predict(&q0, 5, 5).unwrap(), reference.predict(&q0, 5, 5));
    assert!(!g_partial.last_batch_degraded());
    assert!(g_partial.degraded_shards().is_empty());

    hosts.remove(1).shutdown();

    // Default mode: exact-or-fail.
    g_default
        .predict(&q0, 5, 5)
        .expect_err("default mode must fail the batch when a shard is fully down");

    // allow_partial: the exact ranking over the live label subspace.
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        let got = g_partial.predict(&q, 5, 5).expect("degraded batch must answer");
        assert_eq!(got, sub_oracle.predict(&q, 5, 5), "q={qi} degraded ranking");
        assert!(g_partial.last_batch_degraded(), "q={qi} must be flagged degraded");
        assert_eq!(g_partial.degraded_shards(), vec![1u32], "q={qi}");
    }
    assert!(
        g_partial.stats().degraded_batches.load(Ordering::Relaxed) >= queries.rows as u64,
        "every degraded batch must be counted"
    );
    for h in hosts {
        h.shutdown();
    }
}

/// End-to-end degraded serving through the batching coordinator: after a
/// shard dies, `--allow-partial` responses arrive with `degraded = true`
/// and the live shard's exact sub-ranking — zero failed batches.
#[test]
fn coordinator_marks_degraded_responses() {
    let sp = spec(80, 192);
    let model = synth_model(&sp, 4, 0xC0DE);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch);
    let reference = InferenceEngine::new(model.clone(), engine);
    let shards = partition(&model, 2);
    let sub_oracle = InferenceEngine::new(shards[0].model.clone(), engine);
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in shards {
        let h = ShardHost::spawn(shard, host_cfg(engine), "127.0.0.1:0").unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let coord = RemoteShardedCoordinator::start_groups(
        &groups,
        RemoteCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 1,
                max_batch: 4,
                max_batch_delay: Duration::from_micros(200),
                beam: 5,
                topk: 5,
                ..Default::default()
            },
            remote: RemoteConfig {
                round_timeout: Duration::from_millis(500),
                allow_partial: true,
                ..Default::default()
            },
        },
    )
    .expect("start degradable coordinator");
    let queries = synth_queries(&sp, 20, 0xAB1E);
    // Wave 1 (all shards up): full-fidelity responses, not flagged.
    for i in 0..10 {
        let q = queries.row_owned(i);
        let resp = coord.query_blocking(q.clone()).expect("reply");
        assert!(!resp.degraded, "q={i} wrongly flagged degraded");
        assert_eq!(resp.predictions, reference.predict(&q, 5, 5), "q={i}");
    }
    hosts.remove(1).shutdown();
    // Wave 2 (shard 1 gone): degraded responses, never failures.
    for i in 10..queries.rows {
        let q = queries.row_owned(i);
        let resp = coord.query_blocking(q.clone()).expect("degraded reply must arrive");
        assert!(resp.degraded, "q={i} must be flagged degraded");
        assert_eq!(resp.predictions, sub_oracle.predict(&q, 5, 5), "q={i}");
    }
    let rs = coord.remote_stats();
    assert_eq!(rs.failed_batches.load(Ordering::Relaxed), 0, "no batch may fail");
    assert!(rs.degraded_batches.load(Ordering::Relaxed) >= 1);
    assert_eq!(coord.stats().completed.load(Ordering::Relaxed), queries.rows as u64);
    coord.shutdown();
    for h in hosts {
        h.shutdown();
    }
}

/// Circuit breaker + rejoin: a killed replica is ejected after repeated
/// failures; a host restarted on the *same address* is probed once its
/// cooldown lapses, rejoins as healthy, and demonstrably serves rounds
/// again (its expand-frame counter moves).
#[test]
fn killed_then_restarted_replica_rejoins_and_serves() {
    let sp = spec(64, 96);
    let model = synth_model(&sp, 3, 0x4E10);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
    let reference = InferenceEngine::new(model.clone(), engine);
    let shards = partition(&model, 1);
    let shard0 = shards[0].clone();
    let a = ShardHost::spawn(shard0.clone(), host_cfg(engine), "127.0.0.1:0").unwrap();
    let b = ShardHost::spawn(shards.into_iter().next().unwrap(), host_cfg(engine), "127.0.0.1:0")
        .unwrap();
    let addr_a = a.local_addr();
    let groups = vec![vec![addr_a, b.local_addr()]];
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_millis(500),
            eject_after: 2,
            eject_cooldown: Duration::from_millis(50),
            eject_cooldown_cap: Duration::from_millis(200),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 40, 0x9E77);
    let q = |i: usize| queries.row_owned(i);
    assert_eq!(g.predict(&q(0), 5, 5).unwrap(), reference.predict(&q(0), 5, 5));

    a.shutdown();
    // Keep the stream going: every query stays exact on the backup, and
    // the repeated failures open A's circuit.
    for i in 0..20 {
        assert_eq!(
            g.predict(&q(i), 5, 5).expect("backup must absorb the kill"),
            reference.predict(&q(i), 5, 5),
            "q={i} while A is down"
        );
    }
    assert!(
        g.stats().ejections.load(Ordering::Relaxed) >= 1,
        "a dead replica must be ejected by the circuit breaker"
    );
    let phase_a = |g: &RemoteGather| {
        g.replica_phases(0)
            .into_iter()
            .find(|(addr, _, _)| *addr == addr_a)
            .expect("replica A must stay in the health table")
    };
    assert_ne!(phase_a(&g).1, ReplicaPhase::Healthy, "a dead replica cannot be healthy");

    // Restart on the same address (retry: the OS may briefly hold it).
    let mut restarted = None;
    for _ in 0..100 {
        match ShardHost::spawn(shard0.clone(), host_cfg(engine), addr_a) {
            Ok(h) => {
                restarted = Some(h);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
    let restarted = restarted.expect("rebind the killed replica's address");
    // Let every cooldown lapse so A is on probation, then drive traffic:
    // rotation reaches A, the probe succeeds, A rejoins.
    std::thread::sleep(Duration::from_millis(600));
    for i in 20..queries.rows {
        assert_eq!(
            g.predict(&q(i), 5, 5).expect("rejoin traffic"),
            reference.predict(&q(i), 5, 5),
            "q={i} after restart"
        );
    }
    let (_, phase, ewma_ms) = phase_a(&g);
    assert_eq!(phase, ReplicaPhase::Healthy, "restarted replica must rejoin");
    assert!(ewma_ms > 0.0, "rejoined replica must have served (EWMA untouched)");
    let snap = poll_stats(addr_a, &RemoteConfig::default()).expect("poll restarted host");
    assert!(
        snap.counters.get("host.expand_frames").copied().unwrap_or(0) > 0,
        "restarted host never served an Expand round"
    );
    b.shutdown();
    restarted.shutdown();
}

/// Slow-loris replies (every frame written in two chunks around a gap):
/// with a generous round timeout the reader simply blocks through the
/// gap — exact results, zero failovers. With a round timeout shorter
/// than the gap, the mid-frame timeout is treated as a replica failure
/// (connection dropped, round re-issued on the backup) — still exact,
/// never truncation garbage.
#[test]
fn slow_loris_hosts_are_absorbed_without_garbage() {
    let sp = spec(64, 128);
    let model = synth_model(&sp, 4, 0x510E);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), engine);

    // Leg 1: every reply of every shard stutters; timeout far above the
    // gap. The stream must be indistinguishable from a slow-but-correct
    // host.
    let stutter = FaultPlan {
        seed: common::base_seed(),
        stutter: Some(Duration::from_millis(40)),
        ..Default::default()
    };
    let mut hosts = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        let h =
            ShardHost::with_faults(shard, host_cfg(engine), "127.0.0.1:0", stutter.clone()).unwrap();
        groups.push(vec![h.local_addr()]);
        hosts.push(h);
    }
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_secs(5),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 5, 0x70AD);
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(g.predict(&q, 5, 5).unwrap(), reference.predict(&q, 5, 5), "q={qi}");
    }
    assert_eq!(
        g.stats().failovers.load(Ordering::Relaxed),
        0,
        "a patient reader must ride out the stutter without failing over"
    );
    drop(g);
    for h in hosts {
        h.shutdown();
    }

    // Leg 2: the gap exceeds the round timeout, so every read on the
    // slow replica dies mid-frame; the healthy backup must carry the
    // stream bit-exactly.
    let slow = FaultPlan {
        seed: common::base_seed() ^ 1,
        stutter: Some(Duration::from_millis(150)),
        ..Default::default()
    };
    let (primaries, backups, groups) = spawn_faulty_partition(&model, 1, engine, &slow);
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_millis(50),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    for qi in 0..queries.rows {
        let q = queries.row_owned(qi);
        assert_eq!(
            g.predict(&q, 5, 5).expect("backup must carry the slow-loris stream"),
            reference.predict(&q, 5, 5),
            "q={qi} under mid-frame timeouts"
        );
    }
    assert!(g.stats().failovers.load(Ordering::Relaxed) >= 1);
    for h in primaries.into_iter().chain(backups) {
        h.shutdown();
    }
}

/// Hedged retries: once the shard's round histogram is warm, a reply
/// slower than the observed p99 is abandoned for the backup replica.
/// With one replica paused (connected but mute) and a 30 s round
/// timeout, only hedging can keep the stream fast — and it must not
/// change a single bit of the results.
#[test]
fn hedging_reroutes_slow_replies_without_changing_results() {
    let sp = spec(64, 96);
    let model = synth_model(&sp, 3, 0x4ED6);
    let engine = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let reference = InferenceEngine::new(model.clone(), engine);
    let shards = partition(&model, 1);
    let a = ShardHost::with_faults(
        shards[0].clone(),
        host_cfg(engine),
        "127.0.0.1:0",
        FaultPlan::default(),
    )
    .unwrap();
    let b = ShardHost::spawn(shards.into_iter().next().unwrap(), host_cfg(engine), "127.0.0.1:0")
        .unwrap();
    let groups = vec![vec![a.local_addr(), b.local_addr()]];
    let mut g = RemoteGather::connect_groups(
        &groups,
        RemoteConfig {
            round_timeout: Duration::from_secs(30),
            hedge: true,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 120, 0x4ED9);
    // Warm the shard's round histogram past the hedge activation floor.
    let mut qi = 0usize;
    while g.stats().scatter.shard(0).count() < 64 {
        let q = queries.row_owned(qi % queries.rows);
        assert_eq!(g.predict(&q, 5, 5).unwrap(), reference.predict(&q, 5, 5));
        qi += 1;
        assert!(qi < 500, "histogram never warmed");
    }
    a.pause();
    let t0 = Instant::now();
    for i in 0..10 {
        let q = queries.row_owned(i);
        assert_eq!(
            g.predict(&q, 5, 5).expect("hedged query"),
            reference.predict(&q, 5, 5),
            "q={i} under hedging"
        );
    }
    let elapsed = t0.elapsed();
    a.resume();
    assert!(
        g.stats().hedges.load(Ordering::Relaxed) >= 1,
        "a mute active replica must trigger at least one hedge"
    );
    // Without hedging every round on the paused replica would stall for
    // the 30 s round timeout; hedged, the whole stream finishes fast.
    assert!(
        elapsed < Duration::from_secs(10),
        "hedging failed to bound tail latency: {elapsed:?}"
    );
    a.shutdown();
    b.shutdown();
}

/// Satellite: the terminal failover error is diagnosable — it names the
/// attempt count and the last replica address tried, instead of the old
/// bare "round failed with no attempt".
#[test]
fn terminal_failover_error_names_attempts_and_replica() {
    let sp = spec(64, 96);
    let model = synth_model(&sp, 3, 0x7E4D);
    let engine = EngineConfig::default();
    let shards = partition(&model, 1);
    let h = ShardHost::spawn(shards.into_iter().next().unwrap(), host_cfg(engine), "127.0.0.1:0")
        .unwrap();
    let addr = h.local_addr();
    let mut g = RemoteGather::connect_groups(
        &[vec![addr]],
        RemoteConfig {
            round_timeout: Duration::from_millis(200),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let queries = synth_queries(&sp, 1, 0x7E4E);
    let q = queries.row_owned(0);
    h.shutdown();
    let err = g.predict(&q, 5, 5).expect_err("dead partition must fail");
    let msg = err.to_string();
    assert!(msg.contains("attempt"), "error must count attempts: {msg}");
    assert!(
        msg.contains(&addr.to_string()),
        "error must name the last replica tried ({addr}): {msg}"
    );
}
