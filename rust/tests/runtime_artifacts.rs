//! Integration: the PJRT runtime loads the AOT artifacts produced by
//! `make artifacts` and its numerics match the native rust math.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built — `make artifacts` is a python build step the pure-cargo flow
//! may not have run.

use mscm_xmr::inference::sigmoid;
use mscm_xmr::runtime::{Tensor, XlaRuntime};
use mscm_xmr::util::{Json, Rng};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn matmul_artifact_matches_rust_math() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json")).unwrap()).unwrap();
    let geti = |k: &str| meta.get(k).and_then(|v| v.as_f64()).unwrap() as usize;
    let (n, d, b1) = (geti("n"), geti("d"), geti("b1"));

    let rt = XlaRuntime::cpu().unwrap();
    let comp = rt.load_hlo_text(dir.join("matmul_only.hlo.txt")).unwrap();

    let mut rng = Rng::seed_from_u64(3);
    let x = Tensor::new((0..n * d).map(|_| rng.gen_normal() * 0.3).collect(), vec![n, d]);
    let w = Tensor::new(
        (0..d * b1).map(|_| rng.gen_normal() * 0.05).collect(),
        vec![1, d, b1],
    );
    // half the queries masked off
    let mask_vals: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
    let mask = Tensor::new(mask_vals.clone(), vec![n, 1]);
    let ps = Tensor::new(vec![0.5; n], vec![n, 1]);
    let out = comp.run(&[x.clone(), w.clone(), mask, ps]).unwrap();
    assert_eq!(out[0].dims, vec![n, b1]);
    for i in 0..n {
        for c in 0..b1 {
            let mut a = 0.0f32;
            for k in 0..d {
                a += x.data[i * d + k] * w.data[k * b1 + c];
            }
            let want = if mask_vals[i] > 0.0 { 0.5 * sigmoid(a) } else { 0.0 };
            let got = out[0].data[i * b1 + c];
            assert!(
                (want - got).abs() < 1e-4,
                "({i},{c}): want {want} got {got}"
            );
        }
    }
}

#[test]
fn all_artifacts_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    for name in ["matmul_only", "layer_step", "full_inference"] {
        rt.load_hlo_text(dir.join(format!("{name}.hlo.txt")))
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}

#[test]
fn layer_step_beam_is_topb() {
    let Some(dir) = artifacts_dir() else { return };
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json")).unwrap()).unwrap();
    let geti = |k: &str| meta.get(k).and_then(|v| v.as_f64()).unwrap() as usize;
    let (n, d, b1, beam) = (geti("n"), geti("d"), geti("b1"), geti("beam"));
    let rt = XlaRuntime::cpu().unwrap();
    let comp = rt.load_hlo_text(dir.join("layer_step.hlo.txt")).unwrap();
    let mut rng = Rng::seed_from_u64(11);
    let x = Tensor::new((0..n * d).map(|_| rng.gen_normal()).collect(), vec![n, d]);
    let w = Tensor::new(
        (0..d * b1).map(|_| rng.gen_normal() * 0.1).collect(),
        vec![1, d, b1],
    );
    let mask = Tensor::new(vec![1.0; n], vec![n, 1]);
    let ps = Tensor::new(vec![1.0; n], vec![n, 1]);
    let out = comp.run(&[x.clone(), w.clone(), mask, ps]).unwrap();
    let (scores, idx) = (&out[0], &out[1]);
    assert_eq!(scores.dims, vec![n, beam]);
    for i in 0..n {
        // descending and within range
        for k in 1..beam {
            assert!(scores.data[i * beam + k - 1] >= scores.data[i * beam + k]);
        }
        for k in 0..beam {
            let label = idx.data[i * beam + k] as usize;
            assert!(label < b1);
        }
    }
}
