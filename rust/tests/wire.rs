//! Round-trip and fuzz-ish property tests for the shard wire codec
//! (`mscm_xmr::shard::wire`): random beams/candidates/speculation/
//! trace-span sections round-trip bit-exactly through pooled buffers,
//! and every malformed frame — truncated, bad magic, wrong version,
//! unknown type, unknown flag bits, trailing bytes, out-of-range ids —
//! is rejected with a descriptive error instead of reaching the
//! kernels.

use std::io::Cursor;

use mscm_xmr::metrics::{HostSpan, RoundSpan, TraceRecord, EV_FAILOVER, EV_HEDGE};
use mscm_xmr::shard::wire::{
    decode_cands, decode_error, decode_expand, decode_shard_info, decode_traces,
    decode_traces_poll, encode_cands, encode_error, encode_expand, encode_hello,
    encode_shard_info, encode_traces, encode_traces_poll, patch_cands_encode_ns, read_frame,
    CandsHeader, ExpandHeader, MsgType, SpecRound, WireShardInfo, HEADER_LEN, WIRE_VERSION,
};
use mscm_xmr::shard::ShardRound;
use mscm_xmr::sparse::{CsrMatrix, SparseVec};
use mscm_xmr::util::Rng;

/// A random sorted-unique id list in `0..hi` (ascending, as beams and
/// query rows require).
fn rand_ids(rng: &mut Rng, max_len: usize, hi: u32) -> Vec<u32> {
    let len = rng.gen_range(0..max_len + 1);
    let mut ids: Vec<u32> = (0..len).map(|_| rng.gen_range(0..hi as usize) as u32).collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

fn rand_pairs(rng: &mut Rng, max_len: usize, hi: u32) -> Vec<(u32, f32)> {
    rand_ids(rng, max_len, hi)
        .into_iter()
        .map(|i| (i, rng.gen_f32(-2.0, 2.0)))
        .collect()
}

fn rand_queries(rng: &mut Rng, n: usize, dim: usize) -> CsrMatrix {
    let rows: Vec<SparseVec> = (0..n)
        .map(|_| {
            SparseVec::from_pairs(
                rand_ids(rng, dim / 2, dim as u32)
                    .into_iter()
                    .map(|i| (i, rng.gen_f32(-1.0, 1.0)))
                    .collect(),
            )
        })
        .collect();
    CsrMatrix::from_rows(rows, dim)
}

/// One frame's bytes → (type, payload) through the real reader.
fn frame_payload(bytes: &[u8]) -> std::io::Result<(MsgType, Vec<u8>)> {
    let mut payload = Vec::new();
    let ty = read_frame(&mut Cursor::new(bytes), &mut payload)?;
    Ok((ty, payload))
}

#[test]
fn expand_frames_round_trip_randomized() {
    let mut rng = Rng::seed_from_u64(0xE1);
    let dim = 64usize;
    // Pooled decode targets reused across iterations, like a real host.
    let mut x = CsrMatrix::default();
    let mut round = ShardRound::default();
    let mut buf = Vec::new();
    for case in 0..50 {
        let n = rng.gen_range(1..9);
        let queries = rand_queries(&mut rng, n, dim);
        let beams: Vec<Vec<(u32, f32)>> =
            (0..n).map(|_| rand_pairs(&mut rng, 6, 40)).collect();
        let trace = rng.gen_bool(0.5);
        let hdr = ExpandHeader {
            round_id: rng.gen_range(0..1 << 30) as u64,
            layer: rng.gen_range(0..5) as u32,
            beam: rng.gen_range(1..20) as u32,
            speculate: rng.gen_bool(0.5),
            trace,
            // An untraced frame carries no id on the wire and decodes to 0.
            trace_id: if trace { rng.gen_range(1..1 << 30) as u64 } else { 0 },
        };
        encode_expand(&mut buf, &hdr, &queries, &beams, n);
        let (ty, payload) = frame_payload(&buf).expect("valid frame");
        assert_eq!(ty, MsgType::Expand, "case {case}");
        let got = decode_expand(&payload, dim, &mut x, &mut round).expect("decode");
        assert_eq!(got, hdr, "case {case}");
        assert_eq!(x, queries, "case {case}: query matrix round trip");
        assert_eq!(round.n, n);
        for q in 0..n {
            assert_eq!(round.beams[q], beams[q], "case {case} q={q}");
        }
    }
}

#[test]
fn cands_frames_round_trip_with_and_without_speculation() {
    let mut rng = Rng::seed_from_u64(0xCA);
    let mut buf = Vec::new();
    let mut round_out = ShardRound::default();
    let mut spec_out = SpecRound::default();
    for case in 0..50 {
        let n = rng.gen_range(1..7);
        let mut round = ShardRound::default();
        round.ensure(n);
        for c in round.cands.iter_mut().take(n) {
            *c = rand_pairs(&mut rng, 12, 500);
        }
        let with_spec = rng.gen_bool(0.5);
        let mut spec = SpecRound::default();
        if with_spec {
            spec.ensure(n);
            for q in 0..n {
                spec.parents[q] = rand_pairs(&mut rng, 5, 100);
                spec.child_counts[q] = spec.parents[q]
                    .iter()
                    .map(|_| rng.gen_range(0..5) as u32)
                    .collect();
                let total: usize = spec.child_counts[q].iter().map(|&c| c as usize).sum();
                spec.children[q] = (0..total)
                    .map(|i| (i as u32, rng.gen_f32(0.0, 1.0)))
                    .collect();
            }
        }
        let with_span = rng.gen_bool(0.5);
        let span = HostSpan {
            decode_ns: rng.gen_range(0..1 << 20) as u64,
            expand_ns: rng.gen_range(0..1 << 20) as u64,
            encode_ns: rng.gen_range(0..1 << 20) as u64,
            tiers: rng.gen_range(0..4) as u32,
        };
        let rid = rng.gen_range(0..1 << 20) as u64;
        encode_cands(&mut buf, rid, 3, &round, with_spec.then_some(&spec), with_span.then_some(&span));
        let (ty, payload) = frame_payload(&buf).expect("valid frame");
        assert_eq!(ty, MsgType::Cands);
        let hdr: CandsHeader =
            decode_cands(&payload, &mut round_out, &mut spec_out).expect("decode");
        assert_eq!(hdr.round_id, rid, "case {case}");
        assert_eq!(hdr.layer, 3);
        assert_eq!(hdr.has_spec, with_spec);
        assert_eq!(hdr.host_span, with_span.then_some(span), "case {case}");
        assert_eq!(round_out.n, n);
        for q in 0..n {
            assert_eq!(round_out.cands[q], round.cands[q], "case {case} q={q}");
        }
        if with_spec {
            assert_eq!(spec_out.n, n);
            for q in 0..n {
                assert_eq!(spec_out.parents[q], spec.parents[q], "case {case} q={q}");
                assert_eq!(spec_out.child_counts[q], spec.child_counts[q]);
                assert_eq!(spec_out.children[q], spec.children[q]);
            }
        }
    }
}

fn sample_info() -> WireShardInfo {
    WireShardInfo {
        shard_id: 2,
        num_shards: 4,
        depth: 3,
        dim: 1000,
        label_offset: 512,
        num_labels: 256,
        layer_offsets: vec![2, 8, 512],
        layer_nodes: vec![3, 24, 256],
    }
}

#[test]
fn shard_info_and_error_frames_round_trip() {
    let info = sample_info();
    let mut buf = Vec::new();
    encode_shard_info(&mut buf, &info);
    let (ty, payload) = frame_payload(&buf).unwrap();
    assert_eq!(ty, MsgType::ShardInfo);
    assert_eq!(decode_shard_info(&payload).unwrap(), info);

    encode_error(&mut buf, 7, "翻訳 error ünd message");
    let (ty, payload) = frame_payload(&buf).unwrap();
    assert_eq!(ty, MsgType::Error);
    assert_eq!(decode_error(&payload).unwrap(), (7, "翻訳 error ünd message".to_string()));

    encode_hello(&mut buf);
    let (ty, payload) = frame_payload(&buf).unwrap();
    assert_eq!(ty, MsgType::Hello);
    assert!(payload.is_empty());
}

#[test]
fn truncated_frames_are_rejected_at_every_cut() {
    let info = sample_info();
    let mut buf = Vec::new();
    encode_shard_info(&mut buf, &info);
    // Any strict prefix must fail to read — header or payload cut alike.
    for cut in 0..buf.len() {
        let err = frame_payload(&buf[..cut]).expect_err(&format!("prefix of {cut} bytes"));
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut {cut}");
    }
    // A payload that *reads* fully but lies about internal list lengths
    // fails structurally: chop the payload, fix up the frame length.
    let (_, payload) = frame_payload(&buf).unwrap();
    for cut in 0..payload.len() {
        let err = decode_shard_info(&payload[..cut])
            .expect_err(&format!("payload prefix of {cut} bytes"));
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "cut {cut}");
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
}

#[test]
fn truncated_expand_payload_never_panics_and_always_errors() {
    // Fuzz-ish: every prefix of a real Expand payload must decode to a
    // clean error (no panic, no partial acceptance).
    let mut rng = Rng::seed_from_u64(0xF0);
    let dim = 48usize;
    let n = 4usize;
    let queries = rand_queries(&mut rng, n, dim);
    let beams: Vec<Vec<(u32, f32)>> = (0..n).map(|_| rand_pairs(&mut rng, 5, 30)).collect();
    let hdr = ExpandHeader {
        round_id: 9,
        layer: 1,
        beam: 10,
        speculate: true,
        trace: true,
        trace_id: 0xBEEF,
    };
    let mut buf = Vec::new();
    encode_expand(&mut buf, &hdr, &queries, &beams, n);
    let (_, payload) = frame_payload(&buf).unwrap();
    let mut x = CsrMatrix::default();
    let mut round = ShardRound::default();
    for cut in 0..payload.len() {
        assert!(
            decode_expand(&payload[..cut], dim, &mut x, &mut round).is_err(),
            "prefix of {cut} bytes decoded"
        );
    }
    // The full payload still decodes after all those failed attempts
    // (pooled buffers are not corrupted by partial decodes).
    assert_eq!(decode_expand(&payload, dim, &mut x, &mut round).unwrap(), hdr);
    assert_eq!(x, queries);
}

#[test]
fn bad_magic_and_version_mismatch_are_rejected() {
    let mut buf = Vec::new();
    encode_hello(&mut buf);

    let mut bad_magic = buf.clone();
    bad_magic[0] ^= 0xFF;
    let err = frame_payload(&bad_magic).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("magic"), "{err}");

    let mut bad_version = buf.clone();
    let v = (WIRE_VERSION + 1).to_le_bytes();
    bad_version[4..6].copy_from_slice(&v);
    let err = frame_payload(&bad_version).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("version mismatch"), "{err}");

    let mut bad_type = buf.clone();
    bad_type[6] = 0xEE;
    let err = frame_payload(&bad_type).unwrap_err();
    assert!(err.to_string().contains("frame type"), "{err}");

    let mut huge_len = buf;
    huge_len[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = frame_payload(&huge_len).unwrap_err();
    assert!(err.to_string().contains("MAX_FRAME"), "{err}");
}

#[test]
fn structural_violations_in_payloads_are_rejected() {
    let dim = 32usize;
    // Fixed queries so the feature-range case below is deterministic:
    // feature 5 is valid at dim 32 and out of range at dim 2.
    let queries = CsrMatrix::from_rows(
        vec![
            SparseVec::from_pairs(vec![(1, 0.5), (5, 1.0)]),
            SparseVec::from_pairs(vec![(0, 2.0)]),
        ],
        dim,
    );
    let beams = vec![vec![(1u32, 0.5f32), (4, 0.25)], vec![(0u32, 1.0f32)]];
    let hdr = ExpandHeader {
        round_id: 1,
        layer: 0,
        beam: 4,
        speculate: false,
        trace: false,
        trace_id: 0,
    };
    let mut buf = Vec::new();
    encode_expand(&mut buf, &hdr, &queries, &beams, 2);
    let (_, payload) = frame_payload(&buf).unwrap();
    let mut x = CsrMatrix::default();
    let mut round = ShardRound::default();

    // Trailing garbage after a well-formed payload.
    let mut trailing = payload.clone();
    trailing.extend_from_slice(&[0u8; 3]);
    let err = decode_expand(&trailing, dim, &mut x, &mut round).unwrap_err();
    assert!(err.to_string().contains("trailing"), "{err}");

    // A query feature id beyond the host's dimension.
    let err = decode_expand(&payload, 2, &mut x, &mut round).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");

    // Unknown flag bits are reserved: the v3 flag word sits after
    // round_id (u64) + layer (u32) + beam (u32), at payload offset 16.
    let mut bad_flags = payload.clone();
    bad_flags[16] |= 0b100;
    let err = decode_expand(&bad_flags, dim, &mut x, &mut round).unwrap_err();
    assert!(err.to_string().contains("flag"), "{err}");

    // Beam node ids must be strictly ascending: duplicate one.
    let dup_beams = vec![vec![(3u32, 0.5f32), (3, 0.5)], vec![(0u32, 1.0f32)]];
    encode_expand(&mut buf, &hdr, &queries, &dup_beams, 2);
    let (_, payload) = frame_payload(&buf).unwrap();
    let err = decode_expand(&payload, dim, &mut x, &mut round).unwrap_err();
    assert!(err.to_string().contains("ascending"), "{err}");
}

#[test]
fn reader_consumes_exactly_one_frame_from_a_stream() {
    // Two frames back to back on one stream: the reader must leave the
    // second one intact for the next call — the persistent-connection
    // contract.
    let mut stream_bytes = Vec::new();
    let mut buf = Vec::new();
    encode_hello(&mut buf);
    stream_bytes.extend_from_slice(&buf);
    encode_error(&mut buf, 2, "second frame");
    stream_bytes.extend_from_slice(&buf);

    let mut cursor = Cursor::new(stream_bytes.as_slice());
    let mut payload = Vec::new();
    assert_eq!(read_frame(&mut cursor, &mut payload).unwrap(), MsgType::Hello);
    assert_eq!(read_frame(&mut cursor, &mut payload).unwrap(), MsgType::Error);
    assert_eq!(decode_error(&payload).unwrap().1, "second frame");
    assert_eq!(cursor.position() as usize, stream_bytes.len());
    assert_eq!(
        read_frame(&mut cursor, &mut payload).unwrap_err().kind(),
        std::io::ErrorKind::UnexpectedEof
    );
    let _ = HEADER_LEN; // layout constant is part of the public contract
}

/// A populated trace record for codec tests: random identity/timing
/// fields and a handful of spans with event annotations.
fn rand_record(rng: &mut Rng) -> TraceRecord {
    let mut rec = TraceRecord::with_capacity();
    rec.trace_id = rng.gen_range(1..1 << 30) as u64;
    rec.batch = rng.gen_range(1..64) as u32;
    rec.beam = rng.gen_range(1..20) as u32;
    rec.total_ns = rng.gen_range(0..1 << 30) as u64;
    rec.pinned = rng.gen_bool(0.5);
    rec.truncated = rng.gen_range(0..3) as u32;
    for _ in 0..rng.gen_range(0..6) {
        rec.push_span(RoundSpan {
            shard: rng.gen_range(0..8) as u32,
            layer: rng.gen_range(0..5) as u32,
            tx_ns: rng.gen_range(0..1 << 20) as u64,
            round_ns: rng.gen_range(0..1 << 20) as u64,
            wait_ns: rng.gen_range(0..1 << 20) as u64,
            host: HostSpan {
                decode_ns: rng.gen_range(0..1 << 20) as u64,
                expand_ns: rng.gen_range(0..1 << 20) as u64,
                encode_ns: rng.gen_range(0..1 << 20) as u64,
                tiers: rng.gen_range(0..4) as u32,
            },
            events: match rng.gen_range(0..3) {
                0 => EV_HEDGE,
                1 => EV_FAILOVER,
                _ => 0,
            },
        });
    }
    rec
}

#[test]
fn traces_poll_and_dump_round_trip() {
    // The poll: an empty-payload Traces frame, rejected when non-empty.
    let mut buf = Vec::new();
    encode_traces_poll(&mut buf);
    let (ty, payload) = frame_payload(&buf).unwrap();
    assert_eq!(ty, MsgType::Traces);
    assert!(payload.is_empty());
    decode_traces_poll(&payload).unwrap();
    assert!(decode_traces_poll(&[0u8]).is_err());

    // The dump: random records (spans, events, pinned marks) round-trip
    // in order — the codec must preserve the recorder's newest-first
    // export exactly.
    let mut rng = Rng::seed_from_u64(0x7A);
    for case in 0..20 {
        let records: Vec<TraceRecord> =
            (0..rng.gen_range(0..5)).map(|_| rand_record(&mut rng)).collect();
        encode_traces(&mut buf, &records);
        let (ty, payload) = frame_payload(&buf).unwrap();
        assert_eq!(ty, MsgType::Traces);
        assert_eq!(decode_traces(&payload).unwrap(), records, "case {case}");
    }
}

#[test]
fn traces_dump_truncation_and_bad_flags_are_rejected() {
    let mut rng = Rng::seed_from_u64(0x7B);
    let records = vec![rand_record(&mut rng), rand_record(&mut rng)];
    let mut buf = Vec::new();
    encode_traces(&mut buf, &records);
    let (_, payload) = frame_payload(&buf).unwrap();
    // Every strict prefix must fail cleanly (no panic, no partial parse).
    for cut in 0..payload.len() {
        assert!(decode_traces(&payload[..cut]).is_err(), "prefix of {cut} bytes decoded");
    }
    // Trailing garbage after a well-formed dump.
    let mut trailing = payload.clone();
    trailing.extend_from_slice(&[0u8; 2]);
    assert!(decode_traces(&trailing).unwrap_err().to_string().contains("trailing"));
    // Unknown record flag bits: the first record's flag word sits at
    // count (u32) + trace_id (u64) + batch + beam (u32 each) +
    // total_ns (u64) + events (u32) = payload offset 32.
    let mut bad = payload.clone();
    bad[32] |= 0b10;
    let err = decode_traces(&bad).unwrap_err();
    assert!(err.to_string().contains("trace record flags"), "{err}");
}

#[test]
fn traced_cands_sections_survive_truncation_fuzz_and_backpatch() {
    // A Cands reply carrying *both* trailing sections (speculation +
    // host span): every prefix fails cleanly, the full payload decodes,
    // and the encode_ns backpatch lands in the span the peer decodes.
    let mut rng = Rng::seed_from_u64(0x7C);
    let n = 3usize;
    let mut round = ShardRound::default();
    round.ensure(n);
    for c in round.cands.iter_mut().take(n) {
        *c = rand_pairs(&mut rng, 8, 300);
    }
    let mut spec = SpecRound::default();
    spec.ensure(n);
    for q in 0..n {
        spec.parents[q] = rand_pairs(&mut rng, 4, 80);
        spec.child_counts[q] = spec.parents[q].iter().map(|_| 2u32).collect();
        let total = 2 * spec.parents[q].len();
        spec.children[q] = (0..total).map(|i| (i as u32, 0.5f32)).collect();
    }
    let span = HostSpan { decode_ns: 100, expand_ns: 2_000, encode_ns: 0, tiers: 0b11 };
    let mut frame = Vec::new();
    encode_cands(&mut frame, 42, 1, &round, Some(&spec), Some(&span));
    patch_cands_encode_ns(&mut frame, 333);
    let (ty, payload) = frame_payload(&frame).unwrap();
    assert_eq!(ty, MsgType::Cands);
    let mut round_out = ShardRound::default();
    let mut spec_out = SpecRound::default();
    for cut in 0..payload.len() {
        assert!(
            decode_cands(&payload[..cut], &mut round_out, &mut spec_out).is_err(),
            "prefix of {cut} bytes decoded"
        );
    }
    let hdr = decode_cands(&payload, &mut round_out, &mut spec_out).unwrap();
    assert!(hdr.has_spec);
    assert_eq!(
        hdr.host_span,
        Some(HostSpan { decode_ns: 100, expand_ns: 2_000, encode_ns: 333, tiers: 0b11 })
    );
    for q in 0..n {
        assert_eq!(round_out.cands[q], round.cands[q]);
        assert_eq!(spec_out.parents[q], spec.parents[q]);
    }

    // Unknown Cands flag bits: the flag word sits after round_id (u64)
    // + layer (u32), at payload offset 12.
    let mut bad = payload.clone();
    bad[12] |= 0b100;
    let err = decode_cands(&bad, &mut round_out, &mut spec_out).unwrap_err();
    assert!(err.to_string().contains("flag"), "{err}");
}

#[test]
fn slow_loris_frame_either_completes_or_times_out_cleanly() {
    // A frame delivered in two chunks with a gap (the slow-loris shape).
    // The reader must block through the gap and return the intact frame
    // when untimed; under a read timeout shorter than the gap it must
    // surface a timeout-kind error — never InvalidData (which would mean
    // the reader mistook a partial frame for a malformed one) and never
    // a short "successful" read.
    use std::io::{BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    let mut frame = Vec::new();
    encode_error(&mut frame, 9, "sent in two chunks");
    let cut = frame.len() / 2;

    for (timeout, gap) in [
        (None, Duration::from_millis(150)),
        (Some(Duration::from_millis(40)), Duration::from_millis(400)),
    ] {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let frame2 = frame.clone();
        let writer = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&frame2[..cut]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(gap);
            // The timed leg's peer may already be gone; that's fine.
            let _ = s.write_all(&frame2[cut..]);
        });
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(timeout).unwrap();
        let mut r = BufReader::new(stream);
        let mut payload = Vec::new();
        match timeout {
            None => {
                assert_eq!(read_frame(&mut r, &mut payload).unwrap(), MsgType::Error);
                assert_eq!(decode_error(&payload).unwrap().1, "sent in two chunks");
            }
            Some(_) => {
                let err = read_frame(&mut r, &mut payload).unwrap_err();
                assert!(
                    matches!(
                        err.kind(),
                        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                    ),
                    "expected a timeout kind, got {:?}: {err}",
                    err.kind()
                );
            }
        }
        writer.join().unwrap();
    }
}
