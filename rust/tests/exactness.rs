//! Property tests for the paper's central exactness claim: every
//! `(algo, iteration)` engine configuration — and every thread count —
//! produces **bit-identical** predictions on arbitrary models/queries.
//!
//! All randomized models/queries come from the shared seeded harness in
//! `tests/common` (`MSCM_TEST_SEED` overrides the base seed; failures
//! print it for replay) — the same generator every other property suite
//! uses, so skewed/uniform depth, mixed-density chunks, empty chunks,
//! width-1 layers and zero-weight rows are all in scope here too.

mod common;

use std::sync::Arc;

use mscm_xmr::inference::{EngineConfig, InferenceEngine};
use mscm_xmr::sparse::ChunkedMatrix;

#[test]
fn all_configs_identical_on_random_models() {
    common::run_cases(25, |case_id, case| {
        // from_arc cannot build side indexes on a shared model, so the
        // hash configurations need the maps present up front.
        let mut m = case.model.clone();
        m.build_row_maps();
        let model = Arc::new(m);
        let beam = 1 + (case_id as usize % 7);
        let topk = 1 + (case_id as usize % 5);
        let mut reference: Option<Vec<Vec<mscm_xmr::inference::Prediction>>> = None;
        for config in EngineConfig::all() {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let got = engine.predict_batch(&case.queries, beam, topk);
            match &reference {
                None => reference = Some(got),
                Some(r) => assert_eq!(
                    &got,
                    r,
                    "{} diverged ({}, beam={beam})",
                    config.label(),
                    case.shape
                ),
            }
        }
    });
}

#[test]
fn parallel_identical_on_random_models() {
    common::run_cases(10, |_, case| {
        // from_arc cannot build side indexes on a shared model, so the
        // hash configurations need the maps present up front.
        let mut m = case.model.clone();
        m.build_row_maps();
        let model = Arc::new(m);
        for config in EngineConfig::all() {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let serial = engine.predict_batch(&case.queries, 4, 4);
            for threads in [2usize, 5] {
                let par = engine.predict_batch_parallel(&case.queries, 4, 4, threads);
                assert_eq!(par, serial, "{} t={threads} ({})", config.label(), case.shape);
            }
        }
    });
}

#[test]
fn beam_invariants_hold() {
    // Beams never exceed b; predictions are sorted desc; scores in (0,1].
    common::run_cases(15, |case_id, case| {
        // from_arc cannot build side indexes on a shared model, so the
        // hash configurations need the maps present up front.
        let mut m = case.model.clone();
        m.build_row_maps();
        let model = Arc::new(m);
        let engine = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig::all()[(case_id % 8) as usize],
        );
        for beam in [1usize, 3, 10] {
            for preds in engine.predict_batch(&case.queries, beam, beam) {
                assert!(preds.len() <= beam);
                assert!(!preds.is_empty());
                for w in preds.windows(2) {
                    assert!(
                        w[0].score > w[1].score
                            || (w[0].score == w[1].score && w[0].label < w[1].label)
                    );
                }
                for p in &preds {
                    assert!(p.score > 0.0 && p.score <= 1.0);
                    assert!((p.label as usize) < model.num_labels());
                }
            }
        }
    });
}

#[test]
fn chunked_round_trips_on_random_matrices() {
    // ChunkedMatrix::from_csc ∘ to_csc == identity for random partitions
    // — under the seed layout and under random per-chunk storage layouts.
    use mscm_xmr::sparse::ChunkStorage;
    let base = common::base_seed();
    let mut g = common::ModelGen::new(base ^ 0xDA7A);
    for case in 0..50 {
        let (csc, offsets) = g.matrix();
        let with_maps = g.pick(0..2) == 0;
        let mut chunked = ChunkedMatrix::from_csc(&csc, &offsets, with_maps);
        assert_eq!(chunked.to_csc(), csc, "case {case} (seed base {base:#x})");
        let layout: Vec<ChunkStorage> = (0..chunked.num_chunks())
            .map(|_| ChunkStorage::ALL[g.pick(0..3)])
            .collect();
        chunked.apply_layout(&layout);
        assert_eq!(
            chunked.to_csc(),
            csc,
            "case {case} layout {layout:?} (seed base {base:#x})"
        );
    }
}
