//! Property tests for the paper's central exactness claim: every
//! `(algo, iteration)` engine configuration — and every thread count —
//! produces **bit-identical** predictions on arbitrary models/queries.
//!
//! Hand-rolled property harness (seeded generators + many cases; the
//! offline vendor set has no proptest): each case synthesizes a random
//! tree model and query batch and cross-checks all 8 configurations.

use mscm_xmr::data::synthetic::{synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine};
use mscm_xmr::util::Rng;
use std::sync::Arc;

fn random_spec(rng: &mut Rng, case: u64) -> (DatasetSpec, usize) {
    let dim = rng.gen_range(16..600);
    let spec = DatasetSpec {
        name: "prop",
        dim,
        num_labels: rng.gen_range(8..400),
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: rng.gen_range(1..40),
        col_nnz: rng.gen_range(1..24),
        sibling_overlap: rng.gen_f64(),
        zipf_theta: 0.7 + rng.gen_f64(),
    };
    let branching = [2usize, 3, 8, 32][(case % 4) as usize];
    (spec, branching)
}

#[test]
fn all_configs_identical_on_random_models() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for case in 0..25u64 {
        let (spec, branching) = random_spec(&mut rng, case);
        let model = Arc::new(synth_model(&spec, branching, case));
        let x = synth_queries(&spec, 12, case ^ 0x55);
        let beam = 1 + (case as usize % 7);
        let topk = 1 + (case as usize % 5);
        let mut reference: Option<Vec<Vec<mscm_xmr::inference::Prediction>>> = None;
        for config in EngineConfig::all() {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let got = engine.predict_batch(&x, beam, topk);
            match &reference {
                None => reference = Some(got),
                Some(r) => assert_eq!(
                    &got,
                    r,
                    "case {case}: {} diverged (B={branching}, beam={beam})",
                    config.label()
                ),
            }
        }
    }
}

#[test]
fn parallel_identical_on_random_models() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    for case in 0..10u64 {
        let (spec, branching) = random_spec(&mut rng, case);
        let model = Arc::new(synth_model(&spec, branching, case + 1000));
        let x = synth_queries(&spec, 33, case);
        for config in EngineConfig::all() {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let serial = engine.predict_batch(&x, 4, 4);
            for threads in [2usize, 5] {
                let par = engine.predict_batch_parallel(&x, 4, 4, threads);
                assert_eq!(par, serial, "case {case}: {} t={threads}", config.label());
            }
        }
    }
}

#[test]
fn beam_invariants_hold() {
    // Beams never exceed b; predictions are sorted desc; scores in (0,1].
    let mut rng = Rng::seed_from_u64(0xF00D);
    for case in 0..15u64 {
        let (spec, branching) = random_spec(&mut rng, case);
        let model = Arc::new(synth_model(&spec, branching, case + 77));
        let x = synth_queries(&spec, 8, case);
        let engine = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig::all()[(case % 8) as usize],
        );
        for beam in [1usize, 3, 10] {
            for preds in engine.predict_batch(&x, beam, beam) {
                assert!(preds.len() <= beam);
                assert!(!preds.is_empty());
                for w in preds.windows(2) {
                    assert!(
                        w[0].score > w[1].score
                            || (w[0].score == w[1].score && w[0].label < w[1].label)
                    );
                }
                for p in &preds {
                    assert!(p.score > 0.0 && p.score <= 1.0);
                    assert!((p.label as usize) < model.num_labels());
                }
            }
        }
    }
}

#[test]
fn chunked_round_trips_on_random_matrices() {
    // ChunkedMatrix::from_csc ∘ to_csc == identity for random partitions.
    use mscm_xmr::sparse::{ChunkedMatrix, CscMatrix, SparseVec};
    let mut rng = Rng::seed_from_u64(0xDA7A);
    for _ in 0..50 {
        let rows = rng.gen_range(1..80);
        let cols = rng.gen_range(1..60);
        let colvecs: Vec<SparseVec> = (0..cols)
            .map(|_| {
                let nnz = rng.gen_range(0..rows.min(20) + 1);
                SparseVec::from_pairs(
                    (0..nnz)
                        .map(|_| (rng.gen_range(0..rows) as u32, rng.gen_f32(-2.0, 2.0)))
                        .collect(),
                )
            })
            .collect();
        let csc = CscMatrix::from_cols(colvecs, rows);
        // random partition of columns into chunks
        let mut offsets = vec![0u32];
        while (*offsets.last().unwrap() as usize) < cols {
            let last = *offsets.last().unwrap() as usize;
            let step = rng.gen_range(1..(cols - last).min(9) + 1);
            offsets.push((last + step) as u32);
        }
        let chunked = ChunkedMatrix::from_csc(&csc, &offsets, rng.gen_bool(0.5));
        assert_eq!(chunked.to_csc(), csc);
    }
}
