//! Shared seeded property-test harness.
//!
//! Every integration property suite (`exactness.rs`, `planner.rs`,
//! `sharding.rs`, `layout.rs`, `format.rs`, `remote.rs`) draws its
//! randomized models and queries from the one [`ModelGen`] here instead
//! of hand-rolled per-file synthetic setups, so the tricky shapes —
//! skewed and uniform depth, mixed-density chunks, all-empty chunks,
//! width-1 layers, explicit zero weights, empty queries — are exercised
//! by *all* of them.
//!
//! Seeding: the base seed comes from the `MSCM_TEST_SEED` env var when
//! set (CI runs the suites once with the fixed default and once with a
//! job-randomized seed) and is **printed on failure** by [`run_cases`],
//! so any failing case replays with
//! `MSCM_TEST_SEED=<seed> cargo test -q --test <suite>`.

#![allow(dead_code)]

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use mscm_xmr::data::synthetic::{synth_model_skewed, DatasetSpec};
use mscm_xmr::sparse::{CscMatrix, CsrMatrix, SparseVec};
use mscm_xmr::tree::{Layer, XmrModel};
use mscm_xmr::util::Rng;

/// The fixed default base seed (CI job 1; local runs).
pub const DEFAULT_SEED: u64 = 0x5EED_CA5E;

/// Base seed: `MSCM_TEST_SEED` when set, else [`DEFAULT_SEED`].
pub fn base_seed() -> u64 {
    match std::env::var("MSCM_TEST_SEED") {
        Ok(s) => s
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("MSCM_TEST_SEED must be a u64, got '{s}': {e}")),
        Err(_) => DEFAULT_SEED,
    }
}

/// One generated property case: a random tree model plus a matching
/// random query batch.
pub struct GenCase {
    /// The seed this exact case regenerates from.
    pub seed: u64,
    /// Compact shape description for failure messages.
    pub shape: String,
    pub model: XmrModel,
    pub queries: CsrMatrix,
}

impl GenCase {
    /// The batch queries as owned rows (for the online paths).
    pub fn query_rows(&self) -> Vec<SparseVec> {
        (0..self.queries.rows)
            .map(|i| self.queries.row_owned(i))
            .collect()
    }
}

/// Seeded generator of randomized tree models and query batches.
pub struct ModelGen {
    rng: Rng,
    /// Soft cap on a layer's node count (bounds label blow-up so wide
    /// grids over many cases stay fast).
    pub max_parents: usize,
}

impl ModelGen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed),
            max_parents: 400,
        }
    }

    /// A randomized model: random dim/depth, per-chunk width classes
    /// (width-1 layers included), per-chunk density classes from
    /// all-empty through dense-rows territory, occasional explicit zero
    /// weights, and per-layer randomized row-map presence.
    pub fn model(&mut self) -> (XmrModel, String) {
        let rng = &mut self.rng;
        let dim = rng.gen_range(12..160);
        let depth = rng.gen_range(1..5);
        let mut layers: Vec<Layer> = Vec::new();
        let mut parents = 1usize;
        for _ in 0..depth {
            // Degenerate shape: some layers are all width-1 chunks.
            let width_one_layer = rng.gen_bool(0.15);
            let mut offsets = vec![0u32];
            let mut cols: Vec<SparseVec> = Vec::new();
            for _ in 0..parents {
                let width = if width_one_layer || cols.len() >= self.max_parents {
                    1
                } else {
                    match rng.gen_range(0..8) {
                        0 => 1,
                        1..=4 => rng.gen_range(2..5),
                        _ => rng.gen_range(4..9),
                    }
                };
                // One density class per chunk, so whole chunks can be
                // empty, tiny (merge territory) or dense (DenseRows
                // territory).
                let class = rng.gen_range(0..10);
                for _ in 0..width {
                    let nnz = match class {
                        0 => 0,
                        1..=2 => rng.gen_range(1..3),
                        3..=7 => rng.gen_range(1..(dim / 4).max(2)),
                        _ => rng.gen_range(dim * 2 / 3..dim),
                    };
                    let mut pairs = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        let f = rng.gen_range(0..dim) as u32;
                        // Explicit stored zeros must stay inert.
                        let v = if rng.gen_bool(0.05) {
                            0.0
                        } else {
                            rng.gen_f32(-1.5, 1.5)
                        };
                        pairs.push((f, v));
                    }
                    cols.push(SparseVec::from_pairs(pairs));
                }
                offsets.push(cols.len() as u32);
            }
            let with_maps = rng.gen_bool(0.5);
            layers.push(Layer::new(
                CscMatrix::from_cols(cols, dim),
                &offsets,
                with_maps,
            ));
            parents = layers.last().unwrap().num_nodes();
        }
        let model = XmrModel::new(dim, layers);
        let shape = format!(
            "dim={} depth={} labels={}",
            model.dim,
            model.depth(),
            model.num_labels()
        );
        (model, shape)
    }

    /// A randomized query batch over feature dimension `dim` (empty
    /// queries included).
    pub fn queries(&mut self, dim: usize, n: usize) -> CsrMatrix {
        let rng = &mut self.rng;
        let rows: Vec<SparseVec> = (0..n)
            .map(|_| {
                if rng.gen_bool(0.1) {
                    return SparseVec::new();
                }
                let nnz = rng.gen_range(1..(dim / 2).max(2));
                SparseVec::from_pairs(
                    (0..nnz)
                        .map(|_| (rng.gen_range(0..dim) as u32, rng.gen_f32(-1.5, 1.5)))
                        .collect(),
                )
            })
            .collect();
        CsrMatrix::from_rows(rows, dim)
    }

    /// A random CSC matrix plus a valid random chunk partition of its
    /// columns (for matrix-level round-trip properties).
    pub fn matrix(&mut self) -> (CscMatrix, Vec<u32>) {
        let rng = &mut self.rng;
        let rows = rng.gen_range(1..80);
        let cols = rng.gen_range(1..60);
        let colvecs: Vec<SparseVec> = (0..cols)
            .map(|_| {
                let nnz = rng.gen_range(0..rows.min(20) + 1);
                SparseVec::from_pairs(
                    (0..nnz)
                        .map(|_| (rng.gen_range(0..rows) as u32, rng.gen_f32(-2.0, 2.0)))
                        .collect(),
                )
            })
            .collect();
        let csc = CscMatrix::from_cols(colvecs, rows);
        let mut offsets = vec![0u32];
        while (*offsets.last().unwrap() as usize) < cols {
            let last = *offsets.last().unwrap() as usize;
            let step = rng.gen_range(1..(cols - last).min(9) + 1);
            offsets.push((last + step) as u32);
        }
        (csc, offsets)
    }

    /// Uniform draw from a half-open range (exposed so callers share the
    /// case's seed stream instead of hatching their own RNGs).
    pub fn pick(&mut self, r: std::ops::Range<usize>) -> usize {
        self.rng.gen_range(r)
    }
}

/// Generates case `i` under `base`: a decorrelated per-case seed, the
/// model and a query batch drawn from the same stream. `max_parents`
/// bounds layer width (grids that build many engines per case pass a
/// small cap).
pub fn gen_case_capped(base: u64, i: u64, max_parents: usize) -> GenCase {
    let seed = base ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut g = ModelGen::new(seed);
    g.max_parents = max_parents;
    let (model, shape) = g.model();
    let n = g.pick(1..9);
    let queries = g.queries(model.dim, n);
    GenCase {
        seed,
        shape,
        model,
        queries,
    }
}

/// [`gen_case_capped`] at the default size cap.
pub fn gen_case(base: u64, i: u64) -> GenCase {
    gen_case_capped(base, i, 400)
}

/// Runs `cases` generated property cases. If the closure panics, the
/// base seed and the failing case are printed first so the failure
/// replays exactly via `MSCM_TEST_SEED`.
pub fn run_cases(cases: u64, f: impl Fn(u64, &GenCase)) {
    run_cases_capped(cases, 400, f)
}

/// [`run_cases`] with a custom layer-width cap (smaller models for
/// wide configuration grids).
pub fn run_cases_capped(cases: u64, max_parents: usize, f: impl Fn(u64, &GenCase)) {
    let base = base_seed();
    for i in 0..cases {
        let case = gen_case_capped(base, i, max_parents);
        let result = catch_unwind(AssertUnwindSafe(|| f(i, &case)));
        if let Err(payload) = result {
            eprintln!(
                "property case {i} FAILED (shape {}): replay with \
                 MSCM_TEST_SEED={base} (case seed {:#x})",
                case.shape, case.seed
            );
            resume_unwind(payload);
        }
    }
}

/// The shared fixed-shape dataset spec the suites previously each
/// duplicated (used where a *specific* structure is needed rather than a
/// randomized one).
pub fn dataset_spec(name: &'static str, dim: usize, labels: usize) -> DatasetSpec {
    DatasetSpec {
        name,
        dim,
        num_labels: labels,
        paper_dim: dim,
        paper_labels: 0,
        query_nnz: 12,
        col_nnz: 8,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

/// Mixed-density skewed tree: wide dense chunks up top, tiny sparse ones
/// below — the shape where the planner actually mixes methods (and
/// layouts).
pub fn skewed_model(dim: usize, labels: usize, roots: usize, seed: u64) -> XmrModel {
    synth_model_skewed(&dataset_spec("skewed-prop", dim, labels), roots, seed, 0.6)
}
