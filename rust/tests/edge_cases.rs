//! Edge-case and failure-injection tests across the public API.

use std::sync::Arc;

use mscm_xmr::data::synthetic::{layer_sizes, synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::napkinxc::NapkinXcEngine;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::metrics::LatencyHistogram;
use mscm_xmr::sparse::{ChunkedMatrix, CscMatrix, SparseVec};
use mscm_xmr::tree::{Layer, XmrModel};

fn small_spec() -> DatasetSpec {
    DatasetSpec {
        name: "edge",
        dim: 500,
        num_labels: 64,
        paper_dim: 0,
        paper_labels: 0,
        query_nnz: 10,
        col_nnz: 8,
        sibling_overlap: 0.5,
        zipf_theta: 1.0,
    }
}

#[test]
fn beam_larger_than_tree_is_exhaustive() {
    let spec = small_spec();
    let model = synth_model(&spec, 4, 1);
    let engine = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
    );
    let q = synth_queries(&spec, 1, 2).row_owned(0);
    // beam far beyond any layer width: must return all 64 labels ranked
    let preds = engine.predict(&q, 10_000, 10_000);
    assert_eq!(preds.len(), 64);
    let mut labels: Vec<u32> = preds.iter().map(|p| p.label).collect();
    labels.sort_unstable();
    assert_eq!(labels, (0..64).collect::<Vec<u32>>());
}

#[test]
fn topk_larger_than_beam_returns_beam() {
    let spec = small_spec();
    let model = synth_model(&spec, 4, 3);
    let engine = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::DenseLookup),
    );
    let q = synth_queries(&spec, 1, 4).row_owned(0);
    let preds = engine.predict(&q, 3, 50);
    assert_eq!(preds.len(), 3); // beamed to 3 leaves at the bottom
}

#[test]
fn single_label_tree_works() {
    let csc = CscMatrix::from_cols(vec![SparseVec::from_pairs(vec![(0, 1.0)])], 4);
    let model = XmrModel::new(4, vec![Layer::new(csc, &[0, 1], true)]);
    for config in EngineConfig::all() {
        let engine = InferenceEngine::new(model.clone(), config);
        let preds = engine.predict(&SparseVec::from_pairs(vec![(0, 2.0)]), 5, 5);
        assert_eq!(preds.len(), 1, "{}", config.label());
        assert_eq!(preds[0].label, 0);
    }
}

#[test]
fn width_one_chunks_round_trip_and_infer() {
    // B=2 over 5 labels gives chunk widths {2,1} somewhere in the tree.
    assert_eq!(layer_sizes(5, 2), vec![2, 3, 5]);
    let spec = DatasetSpec {
        num_labels: 5,
        ..small_spec()
    };
    let model = synth_model(&spec, 2, 9);
    // uneven chunks exist
    let widths: Vec<usize> = model
        .layers
        .iter()
        .flat_map(|l| (0..l.chunked.num_chunks()).map(|c| l.chunked.chunk_width(c)))
        .collect();
    assert!(widths.contains(&1) || widths.contains(&2));
    let q = synth_queries(&spec, 1, 1).row_owned(0);
    let mut reference = None;
    for config in EngineConfig::all() {
        let engine = InferenceEngine::new(model.clone(), config);
        let p = engine.predict(&q, 2, 2);
        match &reference {
            None => reference = Some(p),
            Some(r) => assert_eq!(&p, r, "{}", config.label()),
        }
    }
}

#[test]
fn chunked_matrix_rejects_and_accepts_partitions() {
    let csc = CscMatrix::from_cols(
        vec![SparseVec::from_pairs(vec![(0, 1.0)]); 6],
        4,
    );
    // single chunk covering everything
    let m = ChunkedMatrix::from_csc(&csc, &[0, 6], false);
    assert_eq!(m.num_chunks(), 1);
    assert_eq!(m.chunk_width(0), 6);
    // all-singleton chunks
    let m = ChunkedMatrix::from_csc(&csc, &[0, 1, 2, 3, 4, 5, 6], true);
    assert_eq!(m.num_chunks(), 6);
    assert_eq!(m.to_csc(), csc);
}

#[test]
fn napkinxc_memory_overhead_positive() {
    let spec = small_spec();
    let model = Arc::new(synth_model(&spec, 8, 5));
    let napkin = NapkinXcEngine::new(Arc::clone(&model));
    assert!(napkin.side_index_bytes() > 0);
    // NapkinXC per-column overhead must exceed MSCM per-chunk hash maps
    let chunk_map_bytes: usize = model
        .layers
        .iter()
        .flat_map(|l| l.chunked.chunks.iter())
        .filter_map(|c| c.row_map.as_ref().map(|m| m.memory_bytes()))
        .sum();
    assert!(
        napkin.side_index_bytes() > chunk_map_bytes / 2,
        "napkin {} vs chunk {}",
        napkin.side_index_bytes(),
        chunk_map_bytes
    );
}

#[test]
fn histogram_is_thread_safe() {
    let h = Arc::new(LatencyHistogram::new());
    std::thread::scope(|s| {
        for t in 0..4 {
            let h = Arc::clone(&h);
            s.spawn(move || {
                for i in 0..1000u64 {
                    h.record(std::time::Duration::from_micros(t * 100 + i % 50));
                }
            });
        }
    });
    assert_eq!(h.count(), 4000);
    assert!(h.mean_ms() > 0.0);
    assert!(h.quantile_ms(0.99) >= h.quantile_ms(0.50));
}

#[test]
fn zero_nnz_model_columns_still_rank() {
    // Columns with no weights at all: activation 0 → σ = 0.5 everywhere.
    let csc = CscMatrix::from_cols(vec![SparseVec::new(); 4], 8);
    let model = XmrModel::new(8, vec![Layer::new(csc, &[0, 4], true)]);
    for config in EngineConfig::all() {
        let engine = InferenceEngine::new(model.clone(), config);
        let preds = engine.predict(&SparseVec::from_pairs(vec![(1, 1.0)]), 4, 4);
        assert_eq!(preds.len(), 4, "{}", config.label());
        for p in preds {
            assert_eq!(p.score, 0.5);
        }
    }
}

#[test]
fn deep_tree_many_layers() {
    // B=2 over 256 labels → 8 layers; stresses the layer loop.
    let spec = DatasetSpec {
        num_labels: 256,
        ..small_spec()
    };
    let model = synth_model(&spec, 2, 3);
    assert_eq!(model.depth(), 8);
    let engine = InferenceEngine::new(
        model,
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );
    let x = synth_queries(&spec, 16, 6);
    let out = engine.predict_batch(&x, 8, 8);
    assert!(out.iter().all(|p| p.len() == 8));
}
