//! API-compatible **stub** of the `xla` PJRT bindings.
//!
//! The real crate wraps the PJRT C API (`xla_extension`); its native
//! closure is not available in this offline build, so this stub provides
//! the exact type surface `mscm_xmr::runtime` compiles against while
//! failing fast at *runtime*: [`PjRtClient::cpu`] returns an error, which
//! the repository's artifact tests and the `xla-smoke` subcommand already
//! treat as "runtime unavailable, skip". On a machine with the vendored
//! XLA closure, point the `xla` path dependency in the workspace
//! `Cargo.toml` at the real crate instead — no source changes needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's boxed error.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built against the stub xla crate; \
         point Cargo.toml's `xla` path at the vendored XLA closure to enable it)"
    ))
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Creating a CPU client always fails in the stub.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name for logs.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compiling is unreachable (no client can be constructed).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing HLO text always fails in the stub.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation;

impl XlaComputation {
    /// Wraps a proto (trivially constructible; compilation fails later).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execution is unreachable in the stub.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetching to host is unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (dense tensor).
pub struct Literal;

impl Literal {
    /// Builds a rank-1 f32 literal (shape-only stub).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshaping always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// Tuple decomposition is unreachable in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Shape query is unreachable in the stub.
    pub fn shape(&self) -> Result<Shape> {
        Err(unavailable("Literal::shape"))
    }

    /// Host copy is unreachable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Literal shapes.
pub enum Shape {
    /// Dense array shape.
    Array(ArrayShape),
    /// Tuple shape.
    Tuple,
}

/// Dense array shape.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not build a client");
        let msg = err.to_string();
        assert!(msg.contains("PJRT runtime unavailable"), "{msg}");
    }

    #[test]
    fn proto_parse_fails() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
