//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors no crates.io dependencies, so this crate
//! reimplements the subset of anyhow's API the repository uses: [`Error`]
//! (a boxed dynamic error with a context chain), [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Display honours the `{:#}` alternate form by printing the full
//! cause chain, matching real anyhow's formatting closely enough for CLI
//! output.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional chain of causes.
///
/// Like the real `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error` itself — that is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A plain-message error (what `anyhow!` and `Error::msg` produce).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer wrapping a cause.
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {:?}", self.context, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

impl Error {
    /// Wraps any standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { inner: Box::new(error) }
    }

    /// Creates an error from a printable message.
    pub fn msg<M: fmt::Display + fmt::Debug + Send + Sync + 'static>(message: M) -> Self {
        Self { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Adds a context layer (outermost message).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            inner: Box::new(ContextError { context: context.to_string(), source: self.inner }),
        }
    }

    /// Downcasts the outermost error to a concrete type.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        let mut cur: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        while let Some(e) = cur {
            if let Some(t) = e.downcast_ref::<T>() {
                return Some(t);
            }
            cur = e.source();
        }
        None
    }

    /// Iterates the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole cause chain, colon-separated.
            let mut first = true;
            for cause in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{cause}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.inner)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Self::new(error)
    }
}

/// Extension trait adding `.context(...)` to results and options.
pub trait Context<T, E> {
    /// Wraps the error with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wraps the error with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Creates an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Returns early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Returns early with an error when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_formats_alternate() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::new(io).context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            let _ = std::fs::metadata("/definitely/not/a/path/xyz")?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn downcast_walks_the_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "root");
        let e = Error::new(io).context("ctx");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<MessageError>().is_none());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }
}
