//! Micro-benchmarks of the four support-intersection iteration methods
//! (paper §4 items 1–4) at the single vector × chunk product level — the
//! innermost hot path of Algorithm 2. Emits `BENCH_iterators.json`
//! (override with `--json <path>`).
//!
//! `cargo bench --bench iterators`

use mscm_xmr::data::synthetic::{paper_suite, synth_model, synth_queries};
use mscm_xmr::sparse::iterators::{
    vec_chunk_binary, vec_chunk_dense, vec_chunk_hash, vec_chunk_marching, DenseScratch,
};
use mscm_xmr::util::bench::{bench_ms, black_box, BenchReport};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let spec = &paper_suite(10)[1]; // amazoncat-13k shape
    eprintln!("building {} model (B=32) ...", spec.name);
    let model = synth_model(spec, 32, 1);
    let x = synth_queries(spec, 64, 2);
    let layer = model.layers.last().unwrap();
    let chunks = &layer.chunked.chunks;
    let n_chunks = chunks.len();
    let mut report = BenchReport::new("iterators");

    println!("\niterator micro-bench: 64 queries x 32 chunks each, {}", spec.name);
    println!("{:<22}{:>14}{:>16}", "method", "ms/pass", "ns/product");
    let passes = 64 * 32;
    let mut scratch = DenseScratch::new(model.dim);

    for method in ["marching", "binary", "hash", "dense"] {
        let stats = bench_ms(2, 50, 2_000.0, || {
            let mut out = vec![0.0f32; 64];
            for qi in 0..64 {
                let q = x.row(qi);
                for c in 0..32 {
                    let chunk = chunks[(qi * 37 + c * 131) % n_chunks].view();
                    let o = &mut out[..chunk.ncols as usize];
                    o.fill(0.0);
                    match method {
                        "marching" => vec_chunk_marching(q, chunk, o),
                        "binary" => vec_chunk_binary(q, chunk, o),
                        "hash" => vec_chunk_hash(q, chunk, o),
                        _ => {
                            scratch.load(chunk);
                            vec_chunk_dense(q, chunk, &scratch, o);
                            scratch.clear(chunk);
                        }
                    }
                    black_box(&o[0]);
                }
            }
        });
        let ns_per_product = stats.mean_ms * 1e6 / passes as f64;
        println!("{:<22}{:>14.3}{:>16.1}", method, stats.mean_ms, ns_per_product);
        report.record(method, ns_per_product, 64, "MSCM vec x chunk");
    }

    // baseline per-column dots for contrast (the non-MSCM inner loop)
    let csc = &layer.csc;
    let stats = bench_ms(2, 50, 2_000.0, || {
        let mut acc = 0.0f32;
        for qi in 0..64 {
            let q = x.row(qi);
            for c in 0..32 {
                let col = csc.col((qi * 37 + c * 131) % csc.cols);
                acc += q.dot_binary_search(col);
            }
        }
        black_box(acc);
    });
    let ns_per_product = stats.mean_ms * 1e6 / passes as f64;
    println!(
        "{:<22}{:>14.3}{:>16.1}   (per-column, 1 col per 'product')",
        "baseline binary dot", stats.mean_ms, ns_per_product
    );
    report.record("baseline-binary-dot", ns_per_product, 64, "per-column dot");

    report.finish(&args);
}
