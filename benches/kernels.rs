//! Scalar vs SIMD kernel tiers at the single vector × chunk product
//! level — the dispatch the planner's tier pass prices. Measures every
//! tiered kernel on the shapes the tiers were built for (tiny chunks,
//! wide chunks, dense-rows probes, merged spans) plus an end-to-end
//! auto-planned engine against the same plan pinned to the scalar tier.
//! Emits `BENCH_kernels.json` (override with `--json <path>`).
//!
//! `cargo bench --bench kernels` — append `-- --quick` for the CI-sized
//! run (smaller model, tighter time budget, same rows).
//!
//! On hardware without a vector unit (or under `MSCM_FORCE_SCALAR=1`)
//! the `*_simd` rows measure the scalar fallback, so the speedup column
//! reads ~1.0 — the report's `meta.simd` field says which case ran.

use mscm_xmr::data::synthetic::{paper_suite, synth_model, synth_queries};
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, KernelPlan, KernelTier, MatmulAlgo,
    PlannerConfig,
};
use mscm_xmr::sparse::iterators::{
    vec_chunk_binary, vec_chunk_binary_simd, vec_chunk_dense, vec_chunk_dense_rows,
    vec_chunk_dense_rows_simd, vec_chunk_dense_simd, vec_chunk_hash, vec_chunk_hash_simd,
    vec_chunk_marching, vec_chunk_marching_simd, DenseScratch,
};
use mscm_xmr::sparse::{ChunkStorage, ChunkedMatrix, CscMatrix, SimdLevel, SparseVec};
use mscm_xmr::util::bench::{bench_ms, black_box, BenchReport};
use mscm_xmr::util::Json;

const DIM: usize = 4096;

/// `nchunks` chunks of `width` columns, each column carrying `per_col`
/// entries on a deterministic stride — wide `per_col` makes the chunk's
/// row union cover most of the dimension (the DenseRows regime), tiny
/// `per_col` makes merged-eligible slivers.
fn chunk_matrix(nchunks: usize, width: usize, per_col: usize) -> ChunkedMatrix {
    let cols: Vec<SparseVec> = (0..nchunks * width)
        .map(|j| {
            let stride = (DIM / per_col).max(1);
            SparseVec::from_pairs(
                (0..per_col)
                    .map(|k| ((k * stride + j % stride) as u32, 0.25 + (j + k) as f32 * 1e-3))
                    .collect(),
            )
        })
        .collect();
    let csc = CscMatrix::from_cols(cols, DIM);
    let offsets: Vec<u32> = (0..=nchunks).map(|c| (c * width) as u32).collect();
    ChunkedMatrix::from_csc(&csc, &offsets, true)
}

/// `n` queries of `nnz` sorted nonzeros spread across the dimension.
fn queries(n: usize, nnz: usize) -> Vec<SparseVec> {
    (0..n)
        .map(|q| {
            let stride = (DIM / nnz).max(1);
            SparseVec::from_pairs(
                (0..nnz)
                    .map(|i| ((i * stride + q % stride) as u32, 1.0 - (i as f32) * 1e-3))
                    .collect(),
            )
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_pair(
    report: &mut BenchReport,
    shape: &str,
    kernel: &str,
    budget_ms: f64,
    products: usize,
    level: SimdLevel,
    mut scalar: impl FnMut(),
    mut simd: impl FnMut(),
) {
    let s = bench_ms(2, 50, budget_ms, &mut scalar);
    let v = bench_ms(2, 50, budget_ms, &mut simd);
    let s_ns = s.mean_ms * 1e6 / products as f64;
    let v_ns = v.mean_ms * 1e6 / products as f64;
    println!(
        "{:<26}{:>12.1}{:>12.1}{:>10.2}x",
        format!("{shape}/{kernel}"),
        s_ns,
        v_ns,
        s_ns / v_ns.max(1e-9)
    );
    report.record(
        &format!("{shape}/{kernel}/scalar"),
        s_ns,
        products,
        "scalar tier",
    );
    report.record(
        &format!("{shape}/{kernel}/simd"),
        v_ns,
        products,
        &format!("simd tier ({})", level.label()),
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let level = SimdLevel::detect();
    let budget = if quick { 150.0 } else { 1_500.0 };
    let nq = if quick { 16 } else { 64 };
    let q_nnz = 64;

    let mut report = BenchReport::new("kernels");
    report.set_meta("simd", Json::Str(level.label().to_string()));
    report.set_meta("quick", Json::Str(quick.to_string()));
    println!("kernel tiers on {} ({} queries x chunk sweep)", level.label(), nq);
    println!("{:<26}{:>12}{:>12}{:>10}", "shape/kernel", "scalar ns", "simd ns", "speedup");

    let x = queries(nq, q_nnz);

    // Tiny chunks: a handful of rows, merged-eligible widths — the
    // regime the tier pass keeps scalar (SIMD setup can't amortize).
    let tiny = chunk_matrix(if quick { 64 } else { 256 }, 4, 2);
    let ntiny = tiny.num_chunks();
    let mut out = vec![0.0f32; 64];
    for (kernel, is_binary) in [("marching", false), ("binary", true)] {
        run_pair(
            &mut report,
            "tiny",
            kernel,
            budget,
            nq * ntiny,
            level,
            || {
                for q in &x {
                    for c in 0..ntiny {
                        let cv = tiny.view(c);
                        let o = &mut out[..cv.ncols as usize];
                        o.fill(0.0);
                        if is_binary {
                            vec_chunk_binary(q.view(), cv, o);
                        } else {
                            vec_chunk_marching(q.view(), cv, o);
                        }
                        black_box(&o[0]);
                    }
                }
            },
            || {
                for q in &x {
                    for c in 0..ntiny {
                        let cv = tiny.view(c);
                        let o = &mut out[..cv.ncols as usize];
                        o.fill(0.0);
                        if is_binary {
                            vec_chunk_binary_simd(q.view(), cv, o, level);
                        } else {
                            vec_chunk_marching_simd(q.view(), cv, o, level);
                        }
                        black_box(&o[0]);
                    }
                }
            },
        );
    }

    // Wide chunks: many stored rows, row maps resident — the hash and
    // dense kernels' regime, and the shapes with emit runs long enough
    // for the lanes to matter.
    let wide = chunk_matrix(if quick { 2 } else { 8 }, 64, 256);
    let nwide = wide.num_chunks();
    let mut scratch = DenseScratch::new(DIM);
    for kernel in ["marching", "hash", "dense"] {
        run_pair(
            &mut report,
            "wide",
            kernel,
            budget,
            nq * nwide,
            level,
            || {
                for c in 0..nwide {
                    let cv = wide.view(c);
                    if kernel == "dense" {
                        scratch.load(cv);
                    }
                    for q in &x {
                        let o = &mut out[..cv.ncols as usize];
                        o.fill(0.0);
                        match kernel {
                            "marching" => vec_chunk_marching(q.view(), cv, o),
                            "hash" => vec_chunk_hash(q.view(), cv, o),
                            _ => vec_chunk_dense(q.view(), cv, &scratch, o),
                        }
                        black_box(&o[0]);
                    }
                    if kernel == "dense" {
                        scratch.clear(cv);
                    }
                }
            },
            || {
                for c in 0..nwide {
                    let cv = wide.view(c);
                    if kernel == "dense" {
                        scratch.load(cv);
                    }
                    for q in &x {
                        let o = &mut out[..cv.ncols as usize];
                        o.fill(0.0);
                        match kernel {
                            "marching" => vec_chunk_marching_simd(q.view(), cv, o, level),
                            "hash" => vec_chunk_hash_simd(q.view(), cv, o, level),
                            _ => vec_chunk_dense_simd(q.view(), cv, &scratch, o, level),
                        }
                        black_box(&o[0]);
                    }
                    if kernel == "dense" {
                        scratch.clear(cv);
                    }
                }
            },
        );
    }

    // DenseRows layout: the direct row-pointer probe — the 8-wide
    // row_ptr gather is the SIMD tier's biggest single win.
    let mut dr = chunk_matrix(if quick { 2 } else { 8 }, 64, 256);
    dr.apply_layout(&vec![ChunkStorage::DenseRows; dr.num_chunks()]);
    let ndr = dr.num_chunks();
    run_pair(
        &mut report,
        "dense-rows",
        "probe",
        budget,
        nq * ndr,
        level,
        || {
            for c in 0..ndr {
                let cv = dr.view(c);
                for q in &x {
                    let o = &mut out[..cv.ncols as usize];
                    o.fill(0.0);
                    vec_chunk_dense_rows(q.view(), cv, o);
                    black_box(&o[0]);
                }
            }
        },
        || {
            for c in 0..ndr {
                let cv = dr.view(c);
                for q in &x {
                    let o = &mut out[..cv.ncols as usize];
                    o.fill(0.0);
                    vec_chunk_dense_rows_simd(q.view(), cv, o, level);
                    black_box(&o[0]);
                }
            }
        },
    );

    // Merged spans: the tiny chunks coalesced — same walks, contiguous
    // arrays (the locality the mscm layer pass groups for).
    let mut merged = chunk_matrix(if quick { 64 } else { 256 }, 4, 2);
    merged.apply_layout(&vec![ChunkStorage::Merged; merged.num_chunks()]);
    let nm = merged.num_chunks();
    run_pair(
        &mut report,
        "merged",
        "binary",
        budget,
        nq * nm,
        level,
        || {
            for q in &x {
                for c in 0..nm {
                    let cv = merged.view(c);
                    let o = &mut out[..cv.ncols as usize];
                    o.fill(0.0);
                    vec_chunk_binary(q.view(), cv, o);
                    black_box(&o[0]);
                }
            }
        },
        || {
            for q in &x {
                for c in 0..nm {
                    let cv = merged.view(c);
                    let o = &mut out[..cv.ncols as usize];
                    o.fill(0.0);
                    vec_chunk_binary_simd(q.view(), cv, o, level);
                    black_box(&o[0]);
                }
            }
        },
    );

    // End to end: the auto plan as resolved (tiers included) against the
    // same plan pinned to the scalar tier — the planner's whole-engine
    // tier win, and the guard that auto never loses to its scalar self.
    let spec = &paper_suite(if quick { 40 } else { 10 })[1];
    eprintln!("building {} model (B=32) for the end-to-end rows ...", spec.name);
    let model = synth_model(spec, 32, 1);
    let xm = synth_queries(spec, nq, 2);
    let pc = PlannerConfig::default();
    let plan = KernelPlan::auto(&model, MatmulAlgo::Mscm, &pc);
    let scalar_plan = plan.clone().with_uniform_tier(KernelTier::Scalar);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let auto = InferenceEngine::new_with_plan(model.clone(), cfg, plan);
    let scalar = InferenceEngine::new_with_plan(model, cfg, scalar_plan);
    for (label, engine) in [("auto-plan", &auto), ("auto-plan-scalar-tier", &scalar)] {
        let mut ws = engine.workspace();
        let mut preds = vec![Vec::new(); nq];
        let stats = bench_ms(2, 50, budget, || {
            engine.predict_range(&xm, 0, nq, 10, 10, &mut ws, &mut preds);
            black_box(&preds[0]);
        });
        let ns = stats.mean_ms * 1e6 / nq as f64;
        println!("{:<26}{:>12.1} ns/query", format!("e2e/{label}"), ns);
        report.record(&format!("e2e/{label}"), ns, nq, "predict_range beam=10");
    }

    report.finish(&args);
}
