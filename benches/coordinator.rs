//! Serving-stack benchmark: coordinator throughput and latency versus
//! direct engine calls — quantifies the L3 overhead (router + batcher +
//! channels) and the benefit of dynamic batching. Emits
//! `BENCH_coordinator.json` (override with `--json <path>`).
//!
//! `cargo bench --bench coordinator`

use std::sync::Arc;
use std::time::{Duration, Instant};

use mscm_xmr::coordinator::{Coordinator, CoordinatorConfig};
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::util::{BenchReport, Json};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let spec = EnterpriseSpec {
        num_labels: 100_000,
        dim: 50_000,
        ..Default::default()
    };
    eprintln!("synthesizing L={} model ...", spec.num_labels);
    let model = Arc::new(spec.build_model());
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let engine = Arc::new(InferenceEngine::from_arc(Arc::clone(&model), cfg));
    let n = 4_000;
    let x = spec.build_queries(n);
    let mut report = BenchReport::new("coordinator");

    // 1. direct engine, single thread (lower bound on service time)
    let mut ws = engine.workspace();
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let t = Instant::now();
    for q in &queries {
        std::hint::black_box(engine.predict_with(q, 10, 10, &mut ws));
    }
    let direct_ms = t.elapsed().as_secs_f64() * 1e3 / n as f64;
    println!("direct single-thread: {direct_ms:.3} ms/query");
    report.record("direct", direct_ms * 1e6, 1, &cfg.label());

    // 2. through the coordinator at increasing worker counts
    for workers in [1usize, 2, 4] {
        let coord = Coordinator::start(
            Arc::clone(&engine),
            CoordinatorConfig {
                workers,
                max_batch: 32,
                max_batch_delay: Duration::from_micros(300),
                beam: 10,
                topk: 10,
                queue_capacity: 100_000,
            },
        );
        let t = Instant::now();
        let rxs: Vec<_> = queries
            .iter()
            .map(|q| coord.submit(q.clone()).unwrap().1)
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t.elapsed().as_secs_f64();
        let s = coord.stats();
        println!(
            "coordinator w={workers}: {:.0} qps, latency {} (mean batch {:.1})",
            n as f64 / wall,
            s.latency.summary(),
            s.mean_batch()
        );
        report.record_extra(
            "coordinator",
            s.latency.quantile_ms(0.5) * 1e6,
            32,
            &cfg.label(),
            vec![
                ("workers", Json::Num(workers as f64)),
                ("qps", Json::Num(n as f64 / wall)),
                ("mean_batch", Json::Num(s.mean_batch())),
            ],
        );
        coord.shutdown();
    }
    report.finish(&args);
}
