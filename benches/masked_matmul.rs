//! End-to-end inference benchmark over the synthetic paper suite — the
//! `cargo bench` entry point behind Tables 1–3 and Figures 3–4 (the full
//! sweep with reports is `repro bench all`; this binary runs a reduced
//! grid sized for CI). Emits `BENCH_masked_matmul.json` (override with
//! `--json <path>`) with one row per (dataset, config, branching, mode).
//!
//! `cargo bench --bench masked_matmul [-- --scale 20 --queries 128]`

use mscm_xmr::repro::{self, BenchOptions};
use mscm_xmr::util::{BenchReport, Json};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let opts = BenchOptions {
        batch_queries: get("--queries", 128),
        online_queries: get("--online", 64),
        scale: get("--scale", 20),
        only: vec![
            "eurlex-4k".into(),
            "amazoncat-13k".into(),
            "amazon-670k".into(),
        ],
        ..Default::default()
    };
    let mut report = BenchReport::new("masked_matmul");
    for branching in [2usize, 8, 32] {
        let rows = repro::bench_table(branching, &opts);
        repro::print_table(branching, &rows);
        repro::print_figure34(branching, &rows, false);
        repro::print_figure34(branching, &rows, true);
        for r in &rows {
            let extra = vec![("branching", Json::Num(branching as f64))];
            report.record_extra(
                &format!("{}:batch", r.dataset),
                r.batch_ms * 1e6,
                opts.batch_queries,
                &r.config.label(),
                extra.clone(),
            );
            report.record_extra(
                &format!("{}:online", r.dataset),
                r.online_ms * 1e6,
                1,
                &r.config.label(),
                extra,
            );
        }
    }
    report.finish(&args);
}
