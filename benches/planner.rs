//! Kernel-planner benchmark: `IterationMethod::Auto` versus every fixed
//! iteration method, batch and online, on a **skewed** tree (wide dense
//! chunks up top, tiny sparse chunks below — the shape where no single
//! method wins) and on a **uniform** tree (the planner's sanity floor:
//! auto must track the best fixed method within noise).
//!
//! Also reports each engine's `side_index_bytes` and `weight_bytes`, and
//! a **layout ablation**: the auto plan once with planner-driven chunk
//! storage (DenseRows/Merged) and once pinned to the seed CSC layout
//! (`PlannerConfig::storage` off) — the memory/latency delta the storage
//! lever buys on top of kernel selection.
//!
//! Emits `BENCH_planner.json` (override with `--json <path>`).
//!
//! `cargo bench --bench planner [-- --labels 30000 --dim 60000 --queries 256]`

use std::sync::Arc;

use mscm_xmr::data::synthetic::{synth_model, synth_model_skewed, synth_queries, DatasetSpec};
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::sparse::CsrMatrix;
use mscm_xmr::tree::XmrModel;
use mscm_xmr::util::{bench_ms, BenchReport, Json};

fn spec(labels: usize, dim: usize) -> DatasetSpec {
    DatasetSpec {
        name: "planner",
        dim,
        num_labels: labels,
        paper_dim: 0,
        paper_labels: 0,
        query_nnz: 60,
        col_nnz: 80,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

struct Measured {
    label: String,
    batch_ms: f64,
    online_ms: f64,
    side_bytes: usize,
    weight_bytes: usize,
}

/// Builds one engine from a map-less model copy (so the side/weight-bytes
/// columns report honest per-configuration overhead) and measures it.
fn measure_one(
    model: &Arc<XmrModel>,
    x: &CsrMatrix,
    beam: usize,
    cfg: EngineConfig,
    pc: &PlannerConfig,
    label: String,
) -> Measured {
    let n = x.rows;
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let mut base = (**model).clone();
    base.drop_row_maps();
    let engine = InferenceEngine::new_with_planner(base, cfg, pc);
    if cfg.iter == IterationMethod::Auto {
        eprintln!("{label} plan:\n{}", engine.plan().summary());
    }
    let stats = bench_ms(1, 3, 4_000.0, || {
        std::hint::black_box(engine.predict_batch(x, beam, 10));
    });
    let batch_ms = stats.mean_ms / n as f64;
    let mut ws = engine.workspace();
    let stats = bench_ms(1, 3, 4_000.0, || {
        for q in &queries {
            std::hint::black_box(engine.predict_with(q, beam, 10, &mut ws));
        }
    });
    Measured {
        label,
        batch_ms,
        online_ms: stats.mean_ms / n as f64,
        side_bytes: engine.side_index_bytes(),
        weight_bytes: engine.weight_bytes(),
    }
}

/// The fixed four, then the auto plan pinned to the CSC layout, then the
/// full auto plan (layouts on) — always last, so `report_tree` can
/// anchor its comparisons.
fn measure(model: &Arc<XmrModel>, x: &CsrMatrix, beam: usize, pc: &PlannerConfig) -> Vec<Measured> {
    let mut rows = Vec::new();
    for iter in IterationMethod::ALL {
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, iter);
        rows.push(measure_one(model, x, beam, cfg, pc, cfg.label()));
    }
    let auto_cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
    let no_layout = PlannerConfig {
        storage: false,
        ..*pc
    };
    rows.push(measure_one(
        model,
        x,
        beam,
        auto_cfg,
        &no_layout,
        "Auto MSCM (csc layout)".into(),
    ));
    rows.push(measure_one(model, x, beam, auto_cfg, pc, auto_cfg.label()));
    rows
}

fn report_tree(name: &str, rows: &[Measured], report: &mut BenchReport) {
    println!("\n[{name}]");
    println!(
        "{:<24} {:>14} {:>14} {:>12} {:>12}",
        "config", "batch ms/q", "online ms/q", "side KiB", "weight KiB"
    );
    for r in rows {
        println!(
            "{:<24} {:>14.4} {:>14.4} {:>12} {:>12}",
            r.label,
            r.batch_ms,
            r.online_ms,
            r.side_bytes / 1024,
            r.weight_bytes / 1024
        );
        report.record_extra(
            name,
            r.batch_ms * 1e6,
            0,
            &r.label,
            vec![
                ("online_ns_per_op", Json::Num(r.online_ms * 1e6)),
                ("side_index_bytes", Json::Num(r.side_bytes as f64)),
                ("weight_bytes", Json::Num(r.weight_bytes as f64)),
            ],
        );
    }
    // Auto vs the best fixed method (batch): the planner's claim. The
    // two auto rows sit at the tail; fixed methods are everything else.
    let auto = rows.last().expect("auto row");
    let auto_csc = &rows[rows.len() - 2];
    let best_fixed = rows[..rows.len() - 2]
        .iter()
        .min_by(|a, b| a.batch_ms.total_cmp(&b.batch_ms))
        .expect("fixed rows");
    println!(
        "auto vs best fixed ({}): {:.4} vs {:.4} ms/q batch ({:+.1}%)",
        best_fixed.label,
        auto.batch_ms,
        best_fixed.batch_ms,
        100.0 * (auto.batch_ms / best_fixed.batch_ms - 1.0)
    );
    println!(
        "layout ablation: planned layouts {:.4} ms/q, {} KiB weights vs \
         csc-only {:.4} ms/q, {} KiB ({:+.1}% bytes)",
        auto.batch_ms,
        auto.weight_bytes / 1024,
        auto_csc.batch_ms,
        auto_csc.weight_bytes / 1024,
        100.0 * (auto.weight_bytes as f64 / auto_csc.weight_bytes.max(1) as f64 - 1.0)
    );
    report.record_extra(
        &format!("{name}-auto-vs-best"),
        auto.batch_ms * 1e6,
        0,
        &best_fixed.label,
        vec![(
            "best_fixed_ns_per_op",
            Json::Num(best_fixed.batch_ms * 1e6),
        )],
    );
    report.record_extra(
        &format!("{name}-layout-ablation"),
        auto.batch_ms * 1e6,
        0,
        "planned layouts vs csc-only",
        vec![
            ("csc_only_ns_per_op", Json::Num(auto_csc.batch_ms * 1e6)),
            ("weight_bytes", Json::Num(auto.weight_bytes as f64)),
            (
                "csc_only_weight_bytes",
                Json::Num(auto_csc.weight_bytes as f64),
            ),
            ("side_index_bytes", Json::Num(auto.side_bytes as f64)),
            (
                "csc_only_side_index_bytes",
                Json::Num(auto_csc.side_bytes as f64),
            ),
        ],
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let labels = get("--labels", 30_000);
    let dim = get("--dim", 60_000);
    let n = get("--queries", 256);
    let beam = get("--beam", 10);
    let calibrate = get("--calibrate", 32);
    let sp = spec(labels, dim);
    let pc = PlannerConfig {
        query_nnz_hint: sp.query_nnz,
        batch_hint: n.clamp(1, 64),
        calibrate,
        ..Default::default()
    };
    let mut report = BenchReport::new("planner");

    eprintln!("synthesizing skewed tree (L={labels}, d={dim}) ...");
    let skewed = Arc::new(synth_model_skewed(&sp, 16, 42, 0.8));
    let x = synth_queries(&sp, n, 7);
    let rows = measure(&skewed, &x, beam, &pc);
    report_tree("skewed-tree", &rows, &mut report);

    eprintln!("synthesizing uniform tree (L={labels}, d={dim}) ...");
    let uniform = Arc::new(synth_model(&sp, 32, 42));
    let x = synth_queries(&sp, n, 8);
    let rows = measure(&uniform, &x, beam, &pc);
    report_tree("uniform-tree", &rows, &mut report);

    report.finish(&args);
}
