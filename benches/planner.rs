//! Kernel-planner benchmark: `IterationMethod::Auto` versus every fixed
//! iteration method, batch and online, on a **skewed** tree (wide dense
//! chunks up top, tiny sparse chunks below — the shape where no single
//! method wins) and on a **uniform** tree (the planner's sanity floor:
//! auto must track the best fixed method within noise).
//!
//! Also reports each engine's `side_index_bytes` — the planner's memory
//! claim: auto materializes hash/dense side indexes only where its plan
//! uses them, so on mixed-density trees it under-spends fixed `hash`.
//!
//! Emits `BENCH_planner.json` (override with `--json <path>`).
//!
//! `cargo bench --bench planner [-- --labels 30000 --dim 60000 --queries 256]`

use std::sync::Arc;

use mscm_xmr::data::synthetic::{synth_model, synth_model_skewed, synth_queries, DatasetSpec};
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo, PlannerConfig,
};
use mscm_xmr::sparse::CsrMatrix;
use mscm_xmr::tree::XmrModel;
use mscm_xmr::util::{bench_ms, BenchReport, Json};

fn spec(labels: usize, dim: usize) -> DatasetSpec {
    DatasetSpec {
        name: "planner",
        dim,
        num_labels: labels,
        paper_dim: 0,
        paper_labels: 0,
        query_nnz: 60,
        col_nnz: 80,
        sibling_overlap: 0.6,
        zipf_theta: 1.0,
    }
}

struct Measured {
    label: String,
    batch_ms: f64,
    online_ms: f64,
    side_bytes: usize,
}

fn measure(model: &Arc<XmrModel>, x: &CsrMatrix, beam: usize, pc: &PlannerConfig) -> Vec<Measured> {
    let n = x.rows;
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let mut configs: Vec<EngineConfig> = IterationMethod::ALL
        .into_iter()
        .map(|iter| EngineConfig::new(MatmulAlgo::Mscm, iter))
        .collect();
    configs.push(EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto));
    let mut rows = Vec::new();
    for cfg in configs {
        // Each engine starts from a map-less model copy and builds
        // exactly what its plan needs, so the side-bytes column reports
        // honest per-configuration overhead (marching/binary = 0, hash =
        // full index, auto = only the hash-planned chunks + scratch).
        let mut base = (**model).clone();
        base.drop_row_maps();
        let engine = InferenceEngine::new_with_planner(base, cfg, pc);
        if cfg.iter == IterationMethod::Auto {
            eprintln!("auto plan:\n{}", engine.plan().summary());
        }
        let stats = bench_ms(1, 3, 4_000.0, || {
            std::hint::black_box(engine.predict_batch(x, beam, 10));
        });
        let batch_ms = stats.mean_ms / n as f64;
        let mut ws = engine.workspace();
        let stats = bench_ms(1, 3, 4_000.0, || {
            for q in &queries {
                std::hint::black_box(engine.predict_with(q, beam, 10, &mut ws));
            }
        });
        let online_ms = stats.mean_ms / n as f64;
        rows.push(Measured {
            label: cfg.label(),
            batch_ms,
            online_ms,
            side_bytes: engine.side_index_bytes(),
        });
    }
    rows
}

fn report_tree(
    name: &str,
    rows: &[Measured],
    report: &mut BenchReport,
) {
    println!("\n[{name}]");
    println!(
        "{:<24} {:>14} {:>14} {:>14}",
        "config", "batch ms/q", "online ms/q", "side KiB"
    );
    for r in rows {
        println!(
            "{:<24} {:>14.4} {:>14.4} {:>14}",
            r.label,
            r.batch_ms,
            r.online_ms,
            r.side_bytes / 1024
        );
        report.record_extra(
            name,
            r.batch_ms * 1e6,
            0,
            &r.label,
            vec![
                ("online_ns_per_op", Json::Num(r.online_ms * 1e6)),
                ("side_index_bytes", Json::Num(r.side_bytes as f64)),
            ],
        );
    }
    // Auto vs the best fixed method (batch): the planner's claim.
    let auto = rows.last().expect("auto row");
    let best_fixed = rows[..rows.len() - 1]
        .iter()
        .min_by(|a, b| a.batch_ms.total_cmp(&b.batch_ms))
        .expect("fixed rows");
    println!(
        "auto vs best fixed ({}): {:.4} vs {:.4} ms/q batch ({:+.1}%)",
        best_fixed.label,
        auto.batch_ms,
        best_fixed.batch_ms,
        100.0 * (auto.batch_ms / best_fixed.batch_ms - 1.0)
    );
    report.record_extra(
        &format!("{name}-auto-vs-best"),
        auto.batch_ms * 1e6,
        0,
        &best_fixed.label,
        vec![(
            "best_fixed_ns_per_op",
            Json::Num(best_fixed.batch_ms * 1e6),
        )],
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let labels = get("--labels", 30_000);
    let dim = get("--dim", 60_000);
    let n = get("--queries", 256);
    let beam = get("--beam", 10);
    let calibrate = get("--calibrate", 32);
    let sp = spec(labels, dim);
    let pc = PlannerConfig {
        query_nnz_hint: sp.query_nnz,
        batch_hint: n.clamp(1, 64),
        calibrate,
        ..Default::default()
    };
    let mut report = BenchReport::new("planner");

    eprintln!("synthesizing skewed tree (L={labels}, d={dim}) ...");
    let skewed = Arc::new(synth_model_skewed(&sp, 16, 42, 0.8));
    let x = synth_queries(&sp, n, 7);
    let rows = measure(&skewed, &x, beam, &pc);
    report_tree("skewed-tree", &rows, &mut report);

    eprintln!("synthesizing uniform tree (L={labels}, d={dim}) ...");
    let uniform = Arc::new(synth_model(&sp, 32, 42));
    let x = synth_queries(&sp, n, 8);
    let rows = measure(&uniform, &x, beam, &pc);
    report_tree("uniform-tree", &rows, &mut report);

    report.finish(&args);
}
