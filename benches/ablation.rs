//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **chunk-order evaluation** (Alg. 3 lines 6–8) — how much of the
//!    batch win comes from evaluating blocks in chunk order (cache
//!    reuse + amortized dense-scratch loads)?
//! 2. **sibling support overlap** (§4 item 2) — MSCM's chunk walk is
//!    only cheaper than per-column walks when siblings share support;
//!    sweep the generator's overlap knob and watch the speedup move.
//! 3. **branching factor** — the paper's claim that larger B gives a
//!    larger MSCM win, isolated on one dataset.
//!
//! Emits `BENCH_ablation.json` (override with `--json <path>`).
//!
//! `cargo bench --bench ablation`

use std::sync::Arc;
use std::time::Instant;

use mscm_xmr::data::synthetic::{measured_sibling_overlap, synth_model, synth_queries, DatasetSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::util::{BenchReport, Json};

fn spec(overlap: f64) -> DatasetSpec {
    DatasetSpec {
        name: "ablation",
        dim: 60_000,
        num_labels: 30_000,
        paper_dim: 0,
        paper_labels: 0,
        query_nnz: 60,
        col_nnz: 100,
        sibling_overlap: overlap,
        zipf_theta: 1.0,
    }
}

fn batch_ms(engine: &InferenceEngine, x: &mscm_xmr::sparse::CsrMatrix) -> f64 {
    std::hint::black_box(engine.predict_batch(x, 10, 10));
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = Instant::now();
        std::hint::black_box(engine.predict_batch(x, 10, 10));
        best = best.min(t.elapsed().as_secs_f64() * 1e3 / x.rows as f64);
    }
    best
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let mut report = BenchReport::new("ablation");

    // --- 1. chunk-order evaluation on/off (dense lookup feels it most) ---
    println!("\n[ablation 1] chunk-order evaluation (Alg. 3 l.6-8), B=32 batch");
    let s = spec(0.6);
    let model = Arc::new(synth_model(&s, 32, 9));
    let x = synth_queries(&s, 512, 10);
    for iter in [IterationMethod::DenseLookup, IterationMethod::Hash] {
        // The switch is per-engine configuration (no process-global
        // state), so two engines over the same shared model compare the
        // two evaluation orders safely.
        let engine = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig::new(MatmulAlgo::Mscm, iter),
        );
        let unordered_engine = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig {
                chunk_order: false,
                ..EngineConfig::new(MatmulAlgo::Mscm, iter)
            },
        );
        let with = batch_ms(&engine, &x);
        let without = batch_ms(&unordered_engine, &x);
        println!(
            "  {:<16} with sort {:.3} ms/q   without {:.3} ms/q   ({:.2}x from chunk order)",
            iter.label(),
            with,
            without,
            without / with
        );
        report.record_extra(
            "chunk-order",
            with * 1e6,
            512,
            iter.label(),
            vec![
                ("without_sort_ns", Json::Num(without * 1e6)),
                ("speedup_x", Json::Num(without / with)),
            ],
        );
    }

    // --- 2. sibling-overlap sweep ---
    println!("\n[ablation 2] sibling support overlap -> MSCM speedup (binary, B=32)");
    for overlap in [0.0, 0.3, 0.6, 0.9] {
        let s = spec(overlap);
        let model = Arc::new(synth_model(&s, 32, 11));
        let measured = measured_sibling_overlap(&model);
        let x = synth_queries(&s, 256, 12);
        let cfg = |algo| EngineConfig::new(algo, IterationMethod::BinarySearch);
        let mscm = batch_ms(
            &InferenceEngine::from_arc(Arc::clone(&model), cfg(MatmulAlgo::Mscm)),
            &x,
        );
        let base = batch_ms(
            &InferenceEngine::from_arc(Arc::clone(&model), cfg(MatmulAlgo::Baseline)),
            &x,
        );
        println!(
            "  overlap knob {overlap:.1} (measured jaccard {measured:.2}): mscm {mscm:.3} ms/q, baseline {base:.3} ms/q -> {:.2}x",
            base / mscm
        );
        report.record_extra(
            "sibling-overlap",
            mscm * 1e6,
            256,
            "Binary Search MSCM",
            vec![
                ("overlap", Json::Num(overlap)),
                ("baseline_ns", Json::Num(base * 1e6)),
            ],
        );
    }

    // --- 4. query reordering (paper §7 future work) ---
    // The paper briefly investigated reordering *queries* (not blocks) to
    // localize memory and "were unable to obtain a performance boost".
    // Reproduce the experiment: sort batch queries by their dominant
    // feature id so similar queries are adjacent, and compare.
    println!("\n[ablation 4] query reordering (paper §7 future work), hash MSCM B=32 batch");
    {
        let s = spec(0.6);
        let model = Arc::new(synth_model(&s, 32, 15));
        let x = synth_queries(&s, 512, 16);
        let engine = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let unordered = batch_ms(&engine, &x);
        // reorder rows by dominant (max |value|) feature id
        let mut order: Vec<usize> = (0..x.rows).collect();
        let dominant = |i: usize| -> u32 {
            let r = x.row(i);
            r.indices
                .iter()
                .zip(r.values)
                .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
                .map(|(&f, _)| f)
                .unwrap_or(0)
        };
        order.sort_by_key(|&i| dominant(i));
        let xr = x.select_rows(&order);
        let reordered = batch_ms(&engine, &xr);
        println!(
            "  unordered {unordered:.3} ms/q   reordered {reordered:.3} ms/q   ({:+.1}% — paper also found no gain)",
            (unordered / reordered - 1.0) * 100.0
        );
        report.record_extra(
            "query-reordering",
            unordered * 1e6,
            512,
            "Hash MSCM",
            vec![("reordered_ns", Json::Num(reordered * 1e6))],
        );
    }

    // --- 3. branching-factor sweep ---
    println!("\n[ablation 3] branching factor -> MSCM speedup (binary search)");
    let s = spec(0.6);
    for b in [2usize, 8, 32] {
        let model = Arc::new(synth_model(&s, b, 13));
        let x = synth_queries(&s, 256, 14);
        let cfg = |algo| EngineConfig::new(algo, IterationMethod::BinarySearch);
        let mscm = batch_ms(
            &InferenceEngine::from_arc(Arc::clone(&model), cfg(MatmulAlgo::Mscm)),
            &x,
        );
        let base = batch_ms(
            &InferenceEngine::from_arc(Arc::clone(&model), cfg(MatmulAlgo::Baseline)),
            &x,
        );
        println!("  B={b:<3} mscm {mscm:.3} ms/q, baseline {base:.3} ms/q -> {:.2}x", base / mscm);
        report.record_extra(
            "branching-factor",
            mscm * 1e6,
            256,
            "Binary Search MSCM",
            vec![
                ("branching", Json::Num(b as f64)),
                ("baseline_ns", Json::Num(base * 1e6)),
            ],
        );
    }

    report.finish(&args);
}
