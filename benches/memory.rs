//! Resident-memory story for the MSCMXMR4 storage tiers — the bench
//! behind the "100M-label scale" ROADMAP item.
//!
//! Four serving configurations of the same model, all loaded from the
//! layout-resolved V4 shard format:
//!
//! - `heap-f32`    — exact f32 weights, parsed onto the heap,
//! - `heap-quant`  — the `--approx` planned layout (f16/int8 chunks),
//!   parsed onto the heap,
//! - `mmap-f32`    — exact weights served straight out of the page
//!   cache via [`mscm_xmr::shard::load_shard_mmap`],
//! - `mmap-quant`  — quantized weights, memory-mapped.
//!
//! For each we report the shard file size, the **heap bytes the load
//! actually pinned** (a byte-tracking `#[global_allocator]` shim — the
//! mmap variants must come in far under the file weight because the
//! weight arrays are borrowed from the mapping), the cold-start parse
//! time, online p50/p99 over a shared query pool, and — for the
//! quantized variants — precision-overlap@k against the exact engine's
//! rankings.
//!
//! Emits `BENCH_memory.json` (override with `--json <path>`).
//! `cargo bench --bench memory` — append `-- --quick` for the CI-sized
//! run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicI64, Ordering};
use std::time::Instant;

use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{
    EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo, PlannerConfig, Prediction,
};
use mscm_xmr::metrics::LatencyHistogram;
use mscm_xmr::repro::precision_overlap_at_k;
use mscm_xmr::shard::{load_shard, load_shard_mmap, partition, save_shard_v4};
use mscm_xmr::sparse::SparseVec;
use mscm_xmr::util::{BenchReport, Json};

const BEAM: usize = 10;
const TOPK: usize = 10;

/// Live-byte tally across the whole process. Frees are subtracted, so
/// after a load the delta is the bytes that survive — the resident
/// footprint of the model, not parse scratch.
static LIVE: AtomicI64 = AtomicI64::new(0);

struct TrackingAlloc;

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            LIVE.fetch_add(layout.size() as i64, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as i64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            LIVE.fetch_add(layout.size() as i64, Ordering::Relaxed);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE.fetch_add(new_size as i64 - layout.size() as i64, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn live_bytes() -> i64 {
    LIVE.load(Ordering::Relaxed)
}

struct VariantResult {
    file_bytes: u64,
    resident_bytes: i64,
    weight_bytes: usize,
    load_ms: f64,
    p50_ms: f64,
    p99_ms: f64,
    preds: Vec<Vec<Prediction>>,
}

/// Cold-loads one shard file (heap parse or mmap), then serves the
/// query pool online through a reused workspace. The resident delta is
/// taken across the load alone so engine-side arenas don't blur the
/// storage comparison.
fn run_variant(path: &Path, mmap: bool, pool: &[SparseVec]) -> VariantResult {
    let file_bytes = std::fs::metadata(path).expect("shard file metadata").len();
    let before = live_bytes();
    let t = Instant::now();
    let shard = if mmap {
        load_shard_mmap(path, false).expect("mmap shard load")
    } else {
        load_shard(path, false).expect("heap shard load")
    };
    let load_ms = t.elapsed().as_secs_f64() * 1e3;
    let resident_bytes = (live_bytes() - before).max(0);
    let weight_bytes: usize = shard.model.layers.iter().map(|l| l.chunked.weight_bytes()).sum();
    let (algo, plan) = shard.plan.clone().expect("a V4 shard always carries a plan");
    let engine = InferenceEngine::new_with_plan(
        shard.model,
        EngineConfig::new(algo, IterationMethod::Auto),
        plan,
    );
    let mut ws = engine.workspace();
    // Warm the arenas so latency quantiles measure steady state.
    let _ = engine.predict_with(&pool[0], BEAM, TOPK, &mut ws);
    let hist = LatencyHistogram::new();
    let mut preds = Vec::with_capacity(pool.len());
    for q in pool {
        let t = Instant::now();
        let ranked = engine.predict_with(q, BEAM, TOPK, &mut ws).to_vec();
        hist.record(t.elapsed());
        preds.push(ranked);
    }
    VariantResult {
        file_bytes,
        resident_bytes,
        weight_bytes,
        load_ms,
        p50_ms: hist.quantile_ms(0.5),
        p99_ms: hist.quantile_ms(0.99),
        preds,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let spec = EnterpriseSpec {
        num_labels: if quick { 20_000 } else { 100_000 },
        dim: if quick { 20_000 } else { 50_000 },
        ..Default::default()
    };
    eprintln!("synthesizing L={} model ...", spec.num_labels);
    let model = spec.build_model();

    let dir = std::env::temp_dir().join(format!("mscm_memory_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench scratch dir");
    let exact_path = dir.join("exact.mscm");
    let quant_path = dir.join("quant.mscm");

    // Two single-shard builds of the same model: the exact plan and the
    // opt-in `--approx` plan that admits the f16/int8 layouts.
    let mut exact = partition(&model, 1).remove(0);
    exact.plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
    save_shard_v4(&exact, &exact_path).expect("write exact shard");
    let mut quant = partition(&model, 1).remove(0);
    quant.plan_auto(
        MatmulAlgo::Mscm,
        &PlannerConfig {
            approx: true,
            ..PlannerConfig::default()
        },
    );
    save_shard_v4(&quant, &quant_path).expect("write quant shard");
    drop(exact);
    drop(quant);

    let pool_size = if quick { 128 } else { 512 };
    let x = spec.build_queries(pool_size);
    let pool: Vec<SparseVec> = (0..pool_size).map(|i| x.row_owned(i)).collect();

    let mut report = BenchReport::new("memory");
    report.set_meta("quick", Json::Str(quick.to_string()));
    report.set_meta("labels", Json::Num(spec.num_labels as f64));
    report.set_meta("dim", Json::Num(spec.dim as f64));

    let variants: [(&str, &Path, bool); 4] = [
        ("heap-f32", &exact_path, false),
        ("heap-quant", &quant_path, false),
        ("mmap-f32", &exact_path, true),
        ("mmap-quant", &quant_path, true),
    ];
    let mut baseline: Option<Vec<Vec<Prediction>>> = None;
    for (label, path, mmap) in variants {
        let r = run_variant(path, mmap, &pool);
        let overlap = baseline
            .as_ref()
            .map(|b| precision_overlap_at_k(b, &r.preds, TOPK));
        println!(
            "{label:<10} file {:>8} KiB  resident {:>8} KiB  load {:>7.1} ms  p50 {:.3} ms  p99 {:.3} ms{}",
            r.file_bytes / 1024,
            r.resident_bytes / 1024,
            r.load_ms,
            r.p50_ms,
            r.p99_ms,
            match overlap {
                Some(o) => format!("  overlap@{TOPK} {o:.4}"),
                None => String::new(),
            }
        );
        let mut extras = vec![
            ("file_bytes", Json::Num(r.file_bytes as f64)),
            ("resident_bytes", Json::Num(r.resident_bytes as f64)),
            ("weight_bytes", Json::Num(r.weight_bytes as f64)),
            ("load_ms", Json::Num(r.load_ms)),
            ("p50_ms", Json::Num(r.p50_ms)),
            ("p99_ms", Json::Num(r.p99_ms)),
            ("mmap", Json::Bool(mmap)),
        ];
        if let Some(o) = overlap {
            extras.push(("precision_overlap_at_k", Json::Num(o)));
        }
        report.record_extra(label, r.p50_ms * 1e6, 1, "mscm/auto", extras);
        if baseline.is_none() {
            baseline = Some(r.preds);
        }
    }

    std::fs::remove_dir_all(&dir).ok();
    report.finish(&args);
}
