//! Transport overhead benchmark: loopback remote scatter-gather versus
//! the in-process sharded engine, per shard count, with and without
//! speculative expansion — the numbers that keep the wire protocol's
//! per-round cost honest. Emits `BENCH_transport.json` (override with
//! `--json <path>`), including the per-layer-round overhead each
//! transport adds over the in-process engine and the measured network
//! rounds per query (speculation should cut them to ceil(depth / 2)).
//!
//! `cargo bench --bench transport [-- --labels 20000 --dim 20000 --queries 256]`

use std::sync::atomic::Ordering;

use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    partition, GatherArena, RemoteConfig, RemoteGather, ShardHost, ShardHostConfig, ShardedEngine,
};
use mscm_xmr::util::{bench_ms, BenchReport, Json};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let spec = EnterpriseSpec {
        num_labels: get("--labels", 20_000),
        dim: get("--dim", 20_000),
        ..Default::default()
    };
    let n = get("--queries", 256);
    let beam = get("--beam", 10);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    eprintln!("synthesizing L={} d={} model ...", spec.num_labels, spec.dim);
    let model = spec.build_model();
    let x = spec.build_queries(n);
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let mut report = BenchReport::new("transport");

    println!(
        "{:>6} {:>10} {:>16} {:>14} {:>14} {:>12}",
        "shards", "transport", "online ms/query", "per-round ns", "rounds/query", "join p50 ms"
    );
    for s in [1usize, 2, 4] {
        // In-process floor: the same layer-synchronized protocol with
        // function calls instead of TCP rounds.
        let sharded = ShardedEngine::from_model(&model, s, cfg);
        let depth = sharded.depth();
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        let stats = bench_ms(1, 3, 4_000.0, || {
            for q in &queries {
                std::hint::black_box(sharded.predict_with(q, beam, 10, &mut wss, &mut arena));
            }
        });
        let inproc_ms = stats.mean_ms / n as f64;
        println!("{s:>6} {:>10} {inproc_ms:>16.4} {:>14} {depth:>14} {:>12}", "in-proc", "-", "-");
        report.record_extra(
            "inprocess-online",
            inproc_ms * 1e6,
            1,
            &cfg.label(),
            vec![("shards", Json::Num(s as f64))],
        );

        // Loopback hosts, one per shard (each serving the identical
        // partition the in-process engine runs).
        let mut hosts = Vec::new();
        let mut groups = Vec::new();
        for shard in partition(&model, s) {
            let host = ShardHost::spawn(
                shard,
                ShardHostConfig {
                    engine: cfg,
                    ..Default::default()
                },
                "127.0.0.1:0",
            )
            .expect("spawn shard host");
            groups.push(vec![host.local_addr()]);
            hosts.push(host);
        }
        for speculate in [false, true] {
            let mut g = RemoteGather::connect_groups(
                &groups,
                RemoteConfig {
                    speculate,
                    ..Default::default()
                },
                None,
            )
            .expect("connect");
            let stats = bench_ms(1, 3, 4_000.0, || {
                for q in &queries {
                    std::hint::black_box(g.predict_with(q, beam, 10).expect("remote predict"));
                }
            });
            let remote_ms = stats.mean_ms / n as f64;
            let st = g.stats();
            let rounds = st.rounds.load(Ordering::Relaxed) as f64;
            let saved = st.spec_rounds_saved.load(Ordering::Relaxed) as f64;
            // Every query processes `depth` layers; `rounds` of them went
            // over the network, `saved` were assembled from speculation.
            let rounds_per_query = depth as f64 * rounds / (rounds + saved).max(1.0);
            // What each *network* round adds over the in-process engine.
            let per_round_ns = (remote_ms - inproc_ms).max(0.0) * 1e6 / rounds_per_query.max(1.0);
            let join_p50 = st.scatter.join_wait.quantile_ms(0.5);
            let label = if speculate { "remote+spec" } else { "remote" };
            println!(
                "{s:>6} {label:>10} {remote_ms:>16.4} {per_round_ns:>14.0} \
                 {rounds_per_query:>14.1} {join_p50:>12.4}"
            );
            report.record_extra(
                if speculate { "remote-online-spec" } else { "remote-online" },
                remote_ms * 1e6,
                1,
                &cfg.label(),
                vec![
                    ("shards", Json::Num(s as f64)),
                    ("overhead_x", Json::Num(remote_ms / inproc_ms.max(1e-9))),
                    ("per_round_overhead_ns", Json::Num(per_round_ns)),
                    (
                        "network_rounds",
                        Json::Num(st.rounds.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "spec_rounds_saved",
                        Json::Num(st.spec_rounds_saved.load(Ordering::Relaxed) as f64),
                    ),
                    ("join_wait_p50_ms", Json::Num(join_p50)),
                ],
            );
        }
        for h in hosts {
            h.shutdown();
        }
    }
    report.finish(&args);
}
