//! Transport overhead benchmark: loopback remote scatter-gather versus
//! the in-process sharded engine, per shard count, with and without
//! speculative expansion — the numbers that keep the wire protocol's
//! per-round cost honest. Emits `BENCH_transport.json` (override with
//! `--json <path>`), including the per-layer-round overhead each
//! transport adds over the in-process engine and the measured network
//! rounds per query (speculation should cut them to ceil(depth / 2)).
//!
//! `cargo bench --bench transport [-- --labels 20000 --dim 20000 --queries 256]`

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    partition, FaultPlan, GatherArena, RemoteConfig, RemoteGather, ShardHost, ShardHostConfig,
    ShardedEngine,
};
use mscm_xmr::util::{bench_ms, BenchReport, Json};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let spec = EnterpriseSpec {
        num_labels: get("--labels", 20_000),
        dim: get("--dim", 20_000),
        ..Default::default()
    };
    let n = get("--queries", 256);
    let beam = get("--beam", 10);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    eprintln!("synthesizing L={} d={} model ...", spec.num_labels, spec.dim);
    let model = spec.build_model();
    let x = spec.build_queries(n);
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let mut report = BenchReport::new("transport");

    println!(
        "{:>6} {:>10} {:>16} {:>14} {:>14} {:>12}",
        "shards", "transport", "online ms/query", "per-round ns", "rounds/query", "join p50 ms"
    );
    for s in [1usize, 2, 4] {
        // In-process floor: the same layer-synchronized protocol with
        // function calls instead of TCP rounds.
        let sharded = ShardedEngine::from_model(&model, s, cfg);
        let depth = sharded.depth();
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        let stats = bench_ms(1, 3, 4_000.0, || {
            for q in &queries {
                std::hint::black_box(sharded.predict_with(q, beam, 10, &mut wss, &mut arena));
            }
        });
        let inproc_ms = stats.mean_ms / n as f64;
        println!("{s:>6} {:>10} {inproc_ms:>16.4} {:>14} {depth:>14} {:>12}", "in-proc", "-", "-");
        report.record_extra(
            "inprocess-online",
            inproc_ms * 1e6,
            1,
            &cfg.label(),
            vec![("shards", Json::Num(s as f64))],
        );

        // Loopback hosts, one per shard (each serving the identical
        // partition the in-process engine runs).
        let mut hosts = Vec::new();
        let mut groups = Vec::new();
        for shard in partition(&model, s) {
            let host = ShardHost::spawn(
                shard,
                ShardHostConfig {
                    engine: cfg,
                    ..Default::default()
                },
                "127.0.0.1:0",
            )
            .expect("spawn shard host");
            groups.push(vec![host.local_addr()]);
            hosts.push(host);
        }
        for speculate in [false, true] {
            let mut g = RemoteGather::connect_groups(
                &groups,
                RemoteConfig {
                    speculate,
                    ..Default::default()
                },
                None,
            )
            .expect("connect");
            let stats = bench_ms(1, 3, 4_000.0, || {
                for q in &queries {
                    std::hint::black_box(g.predict_with(q, beam, 10).expect("remote predict"));
                }
            });
            let remote_ms = stats.mean_ms / n as f64;
            let st = g.stats();
            let rounds = st.rounds.load(Ordering::Relaxed) as f64;
            let saved = st.spec_rounds_saved.load(Ordering::Relaxed) as f64;
            // Every query processes `depth` layers; `rounds` of them went
            // over the network, `saved` were assembled from speculation.
            let rounds_per_query = depth as f64 * rounds / (rounds + saved).max(1.0);
            // What each *network* round adds over the in-process engine.
            let per_round_ns = (remote_ms - inproc_ms).max(0.0) * 1e6 / rounds_per_query.max(1.0);
            let join_p50 = st.scatter.join_wait.quantile_ms(0.5);
            let label = if speculate { "remote+spec" } else { "remote" };
            println!(
                "{s:>6} {label:>10} {remote_ms:>16.4} {per_round_ns:>14.0} \
                 {rounds_per_query:>14.1} {join_p50:>12.4}"
            );
            report.record_extra(
                if speculate { "remote-online-spec" } else { "remote-online" },
                remote_ms * 1e6,
                1,
                &cfg.label(),
                vec![
                    ("shards", Json::Num(s as f64)),
                    ("overhead_x", Json::Num(remote_ms / inproc_ms.max(1e-9))),
                    ("per_round_overhead_ns", Json::Num(per_round_ns)),
                    (
                        "network_rounds",
                        Json::Num(st.rounds.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "spec_rounds_saved",
                        Json::Num(st.spec_rounds_saved.load(Ordering::Relaxed) as f64),
                    ),
                    ("join_wait_p50_ms", Json::Num(join_p50)),
                ],
            );
        }
        for h in hosts {
            h.shutdown();
        }
    }

    // ------------------------------------------------------------------
    // Failover recovery: 2 replicas of a 1-shard partition, kill one
    // mid-stream, and time the first query that actually absorbs the
    // dead replica (timeout + reconnect + byte-identical re-send) — the
    // serving cost of losing a replica.
    // ------------------------------------------------------------------
    {
        let host_cfg = ShardHostConfig {
            engine: cfg,
            ..Default::default()
        };
        let shards = partition(&model, 1);
        let a = ShardHost::spawn(shards[0].clone(), host_cfg.clone(), "127.0.0.1:0").unwrap();
        let b = ShardHost::spawn(
            shards.into_iter().next().unwrap(),
            host_cfg.clone(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut g = RemoteGather::connect_groups(
            &[vec![a.local_addr(), b.local_addr()]],
            RemoteConfig {
                round_timeout: Duration::from_millis(200),
                ..Default::default()
            },
            None,
        )
        .expect("connect replicated shard");
        for q in queries.iter().take(8) {
            g.predict_with(q, beam, 10).expect("warm");
        }
        a.kill();
        let before = g.stats().failovers.load(Ordering::Relaxed);
        let mut recovery_ms = 0.0f64;
        for q in &queries {
            let t0 = Instant::now();
            g.predict_with(q, beam, 10).expect("query must survive the kill");
            if g.stats().failovers.load(Ordering::Relaxed) > before {
                // This is the query whose round hit the dead replica and
                // failed over: its latency is time-to-first-good-reply.
                recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
                break;
            }
        }
        println!("failover: time-to-first-good-reply after replica kill = {recovery_ms:.3} ms");
        report.record_extra(
            "failover-first-good-reply",
            recovery_ms * 1e6,
            1,
            &cfg.label(),
            vec![("shards", Json::Num(1.0)), ("replicas", Json::Num(2.0))],
        );
        b.shutdown();
    }

    // ------------------------------------------------------------------
    // Hedged vs unhedged tail latency under an injected slow replica:
    // one replica is frozen mid-stream (connected but mute — the
    // pathological slow replica); unhedged, every round that lands on it
    // eats the full round timeout before failing over; hedged, the read
    // is abandoned at the shard's observed p99 and re-issued. Results
    // are bit-identical either way — only the tail moves.
    // ------------------------------------------------------------------
    {
        let host_cfg = ShardHostConfig {
            engine: cfg,
            ..Default::default()
        };
        let round_timeout = Duration::from_millis(100);
        println!(
            "{:>10} {:>12} {:>12} {:>10}",
            "hedging", "p99 ms", "mean ms", "hedges"
        );
        for hedge in [false, true] {
            let shards = partition(&model, 1);
            // The pause/resume latch rides a no-op fault plan.
            let a = ShardHost::with_faults(
                shards[0].clone(),
                host_cfg.clone(),
                "127.0.0.1:0",
                FaultPlan::default(),
            )
            .unwrap();
            let b = ShardHost::spawn(
                shards.into_iter().next().unwrap(),
                host_cfg.clone(),
                "127.0.0.1:0",
            )
            .unwrap();
            let mut g = RemoteGather::connect_groups(
                &[vec![a.local_addr(), b.local_addr()]],
                RemoteConfig {
                    round_timeout,
                    hedge,
                    ..Default::default()
                },
                None,
            )
            .expect("connect replicated shard");
            // Warm the round histogram past the hedge activation floor.
            let mut qi = 0usize;
            while g.stats().scatter.shard(0).count() < 80 {
                g.predict_with(&queries[qi % queries.len()], beam, 10).expect("warm");
                qi += 1;
            }
            a.pause();
            let mut lat_ms: Vec<f64> = Vec::with_capacity(queries.len());
            for q in &queries {
                let t0 = Instant::now();
                g.predict_with(q, beam, 10).expect("query under a mute replica");
                lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            a.resume();
            lat_ms.sort_by(f64::total_cmp);
            let idx = ((lat_ms.len() as f64 * 0.99).ceil() as usize).max(1) - 1;
            let p99 = lat_ms[idx.min(lat_ms.len() - 1)];
            let mean = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
            let hedges = g.stats().hedges.load(Ordering::Relaxed);
            let label = if hedge { "hedged" } else { "unhedged" };
            println!("{label:>10} {p99:>12.3} {mean:>12.3} {hedges:>10}");
            report.record_extra(
                if hedge { "slow-replica-hedged" } else { "slow-replica-unhedged" },
                p99 * 1e6,
                1,
                &cfg.label(),
                vec![
                    ("p99_ms", Json::Num(p99)),
                    ("mean_ms", Json::Num(mean)),
                    ("hedges", Json::Num(hedges as f64)),
                    ("round_timeout_ms", Json::Num(round_timeout.as_secs_f64() * 1e3)),
                ],
            );
            a.shutdown();
            b.shutdown();
        }
    }
    report.finish(&args);
}
