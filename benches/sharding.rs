//! Scatter-gather overhead benchmark: per-query latency of the sharded
//! engine versus the single unsharded engine, as a function of shard
//! count — the number future PRs watch to keep the gather stage cheap.
//! Emits `BENCH_sharding.json` (override with `--json <path>`), including
//! the per-layer-round overhead the pooled protocol must not regress.
//!
//! `cargo bench --bench sharding [-- --labels 50000 --dim 50000 --queries 512]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{GatherArena, ShardedCoordinator, ShardedCoordinatorConfig, ShardedEngine};
use mscm_xmr::util::{bench_ms, BenchReport, Json};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let spec = EnterpriseSpec {
        num_labels: get("--labels", 50_000),
        dim: get("--dim", 50_000),
        ..Default::default()
    };
    let n = get("--queries", 512);
    let beam = get("--beam", 10);
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    eprintln!("synthesizing L={} d={} model ...", spec.num_labels, spec.dim);
    let model = spec.build_model();
    let x = spec.build_queries(n);
    let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let mut report = BenchReport::new("sharding");

    // Unsharded baseline: the floor every shard count is compared to.
    let single = InferenceEngine::new(model.clone(), cfg);
    let depth = single.model().depth();
    let mut ws = single.workspace();
    let stats = bench_ms(1, 3, 5_000.0, || {
        for q in &queries {
            std::hint::black_box(single.predict_with(q, beam, 10, &mut ws));
        }
    });
    let single_ms = stats.mean_ms / n as f64;
    println!("unsharded online:            {single_ms:.4} ms/query");
    report.record("unsharded-online", single_ms * 1e6, 1, &cfg.label());

    println!(
        "{:>6} {:>16} {:>16} {:>12} {:>14} {:>14}",
        "shards", "online ms/query", "batch ms/query", "overhead", "coord p50 ms", "coord qps"
    );
    for s in [1usize, 2, 4, 8] {
        let sharded = ShardedEngine::from_model(&model, s, cfg);

        // Online scatter-gather, workspace/arena-reusing like the
        // unsharded baseline above (sequential over shards — the worst
        // case for gather overhead accounting).
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        let stats = bench_ms(1, 3, 5_000.0, || {
            for q in &queries {
                std::hint::black_box(sharded.predict_with(q, beam, 10, &mut wss, &mut arena));
            }
        });
        let online_ms = stats.mean_ms / n as f64;

        // Batch scatter-gather with one thread per shard.
        let stats = bench_ms(1, 3, 5_000.0, || {
            std::hint::black_box(sharded.predict_batch(&x, beam, 10, true));
        });
        let batch_ms = stats.mean_ms / n as f64;

        // End-to-end through the sharded coordinator at open-loop load.
        let coord = ShardedCoordinator::start(
            Arc::new(ShardedEngine::from_model(&model, s, cfg)),
            ShardedCoordinatorConfig {
                base: CoordinatorConfig {
                    workers: 2,
                    max_batch: 32,
                    max_batch_delay: Duration::from_micros(300),
                    beam,
                    topk: 10,
                    ..Default::default()
                },
                shard_workers: 2,
            },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = queries
            .iter()
            .filter_map(|q| coord.submit(q.clone()).ok().map(|(_, rx)| rx))
            .collect();
        let served = rxs.len();
        for rx in rxs {
            rx.recv().ok();
        }
        let qps = served as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        let p50 = coord.stats().latency.quantile_ms(0.5);
        coord.shutdown();

        let overhead = online_ms / single_ms.max(1e-9);
        // The per-layer scatter-gather round cost: what each of the
        // `depth` synchronization rounds adds over the unsharded search.
        let per_round_ns = (online_ms - single_ms).max(0.0) * 1e6 / depth as f64;
        println!(
            "{s:>6} {online_ms:>16.4} {batch_ms:>16.4} {overhead:>11.2}x {p50:>14.3} {qps:>10.0} qps"
        );
        report.record_extra(
            "sharded-online",
            online_ms * 1e6,
            1,
            &cfg.label(),
            vec![
                ("shards", Json::Num(s as f64)),
                ("overhead_x", Json::Num(overhead)),
                ("per_round_overhead_ns", Json::Num(per_round_ns)),
            ],
        );
        report.record_extra(
            "sharded-batch",
            batch_ms * 1e6,
            n,
            &cfg.label(),
            vec![("shards", Json::Num(s as f64))],
        );
        report.record_extra(
            "sharded-coordinator",
            p50 * 1e6,
            32,
            &cfg.label(),
            vec![("shards", Json::Num(s as f64)), ("qps", Json::Num(qps))],
        );
    }
    report.finish(&args);
}
