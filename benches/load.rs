//! Open-loop load generator against the in-process sharded serving
//! stack — the under-load story ROADMAP item 2 tracks.
//!
//! Closed-loop benches (`benches/coordinator.rs`) submit the next query
//! only after the previous reply, so the arrival rate collapses to
//! whatever the server sustains and queueing delay is invisible. This
//! bench is **open-loop**: arrivals are scheduled on a fixed clock
//! (`t_i = i/λ`) regardless of completions, and each query's latency is
//! measured from its *scheduled* arrival to its reply — queue growth is
//! charged to latency instead of silently throttling the offered rate
//! (no coordinated omission).
//!
//! The query mix is Zipfian over a fixed pool (popular queries repeat,
//! as production traffic does). The sweep offers fractions of a
//! measured closed-loop capacity probe, through saturation; a rate
//! counts as *sustained* when the achieved throughput (arrivals /
//! wall time including drain) stays within 90% of the offered rate
//! with nothing shed. Emits `BENCH_load.json` (override with
//! `--json <path>`) with per-rate p50/p99/p999 and the max sustained
//! QPS in the run metadata.
//!
//! `cargo bench --bench load` — append `-- --quick` for the CI-sized
//! run.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mscm_xmr::coordinator::{CoordinatorConfig, Response};
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, IterationMethod, MatmulAlgo};
use mscm_xmr::metrics::LatencyHistogram;
use mscm_xmr::shard::{ShardedCoordinator, ShardedCoordinatorConfig, ShardedEngine};
use mscm_xmr::sparse::SparseVec;
use mscm_xmr::util::rng::Zipf;
use mscm_xmr::util::{BenchReport, Json, Rng};

const SHARDS: usize = 4;
const BEAM: usize = 10;
const TOPK: usize = 10;

struct RateResult {
    offered: f64,
    achieved: f64,
    completed: usize,
    shed: usize,
    hist: Arc<LatencyHistogram>,
}

/// One open-loop run: `n` arrivals at `offered` QPS, Zipf-drawn from
/// `pool`. Latency is scheduled-arrival → reply; submissions the
/// bounded router refuses are counted as shed, not retried.
fn run_rate(
    coord: &ShardedCoordinator,
    pool: &[SparseVec],
    zipf: &Zipf,
    rng: &mut Rng,
    offered: f64,
    n: usize,
) -> RateResult {
    let hist = Arc::new(LatencyHistogram::new());
    let interval = Duration::from_secs_f64(1.0 / offered);
    let (done_tx, done_rx) = mpsc::channel::<(Instant, mpsc::Receiver<Response>)>();
    let collector = {
        let hist = Arc::clone(&hist);
        std::thread::spawn(move || {
            let mut completed = 0usize;
            while let Ok((scheduled, rx)) = done_rx.recv() {
                if rx.recv().is_ok() {
                    hist.record(scheduled.elapsed());
                    completed += 1;
                }
            }
            completed
        })
    };
    let start = Instant::now();
    let mut shed = 0usize;
    for i in 0..n {
        let target = start + interval.mul_f64(i as f64);
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let q = &pool[zipf.sample(rng)];
        match coord.submit(q.clone()) {
            Ok((_, rx)) => done_tx.send((target, rx)).expect("collector alive"),
            Err(_) => shed += 1,
        }
    }
    drop(done_tx);
    let completed = collector.join().expect("collector join");
    let wall = start.elapsed().as_secs_f64();
    RateResult {
        offered,
        achieved: completed as f64 / wall,
        completed,
        shed,
        hist,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let spec = EnterpriseSpec {
        num_labels: if quick { 20_000 } else { 100_000 },
        dim: if quick { 20_000 } else { 50_000 },
        ..Default::default()
    };
    eprintln!("synthesizing L={} model ...", spec.num_labels);
    let model = spec.build_model();
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let engine = Arc::new(ShardedEngine::from_model(&model, SHARDS, cfg));
    let coord = ShardedCoordinator::start(
        Arc::clone(&engine),
        ShardedCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 32,
                max_batch_delay: Duration::from_micros(300),
                beam: BEAM,
                topk: TOPK,
                queue_capacity: 1_000_000,
            },
            shard_workers: 1,
            ..Default::default()
        },
    );

    let pool_size = if quick { 256 } else { 1024 };
    let x = spec.build_queries(pool_size);
    let pool: Vec<SparseVec> = (0..pool_size).map(|i| x.row_owned(i)).collect();
    let zipf = Zipf::new(pool_size, 1.0);
    let mut rng = Rng::seed_from_u64(0x10AD);

    let mut report = BenchReport::new("load");
    report.set_meta("quick", Json::Str(quick.to_string()));
    report.set_meta("labels", Json::Num(spec.num_labels as f64));
    report.set_meta("shards", Json::Num(SHARDS as f64));

    // Closed-loop capacity probe: a burst submitted all at once keeps
    // every worker busy; its throughput anchors the sweep's rates.
    let probe_n = if quick { 600 } else { 2_000 };
    for _ in 0..probe_n / 4 {
        coord
            .query_blocking(pool[zipf.sample(&mut rng)].clone())
            .expect("warmup reply");
    }
    let t = Instant::now();
    let rxs: Vec<_> = (0..probe_n)
        .map(|_| coord.submit(pool[zipf.sample(&mut rng)].clone()).expect("probe submit").1)
        .collect();
    for rx in rxs {
        rx.recv().expect("probe reply");
    }
    let capacity = probe_n as f64 / t.elapsed().as_secs_f64();
    eprintln!("closed-loop capacity probe: {capacity:.0} qps");
    report.set_meta("capacity_probe_qps", Json::Num(capacity));

    // The sweep: well below, near, and past the probe — the overload
    // point shows up as achieved < offered plus a latency cliff.
    let secs = if quick { 1.5 } else { 4.0 };
    let mut max_sustained = 0.0f64;
    for frac in [0.25, 0.5, 0.75, 0.9, 1.1] {
        let offered = capacity * frac;
        let n = ((offered * secs) as usize).clamp(100, 100_000);
        let r = run_rate(&coord, &pool, &zipf, &mut rng, offered, n);
        let sustained = r.shed == 0 && r.achieved >= 0.9 * r.offered;
        if sustained {
            max_sustained = max_sustained.max(r.achieved);
        }
        println!(
            "offered {:.0} qps ({frac:.2}x): achieved {:.0} qps shed={} {} {}",
            r.offered,
            r.achieved,
            r.shed,
            r.hist.summary(),
            if sustained { "[sustained]" } else { "[saturated]" }
        );
        report.record_extra(
            "open-loop",
            r.hist.quantile_ms(0.5) * 1e6,
            32,
            &cfg.label(),
            vec![
                ("offered_qps", Json::Num(r.offered)),
                ("achieved_qps", Json::Num(r.achieved)),
                ("completed", Json::Num(r.completed as f64)),
                ("shed", Json::Num(r.shed as f64)),
                ("p50_ms", Json::Num(r.hist.quantile_ms(0.5))),
                ("p99_ms", Json::Num(r.hist.quantile_ms(0.99))),
                ("p999_ms", Json::Num(r.hist.quantile_ms(0.999))),
                ("max_ms", Json::Num(r.hist.max_ms())),
                ("sustained", Json::Bool(sustained)),
            ],
        );
    }
    println!("max sustained: {max_sustained:.0} qps");
    report.set_meta("max_sustained_qps", Json::Num(max_sustained));
    coord.shutdown();
    report.finish(&args);
}
