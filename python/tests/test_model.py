"""L2 correctness: layer step and full inference against references, and
AOT lowering sanity (the HLO text must exist, parse and contain no
TPU-only custom calls)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import layer_step_ref, mscm_masked_matmul_ref


def _case(seed, n=4, d=32, c=3, b=8):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((c, d, b)) / np.sqrt(d), jnp.float32)
    mask = jnp.asarray((rng.random((n, c)) < 0.7), jnp.float32)
    ps = jnp.asarray(rng.random((n, c)) * np.asarray(mask), jnp.float32)
    return x, w, mask, ps


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_layer_step_matches_reference(seed, beam):
    x, w, mask, ps = _case(seed)
    got_s, got_i = model.layer_step(x, w, mask, ps, beam=beam)
    want_s, want_i = layer_step_ref(x, w, mask, ps, beam)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-6)
    # indices may tie-swap only where scores tie; check scores at indices
    n = x.shape[0]
    scores = np.asarray(mscm_masked_matmul_ref(x, w, mask, ps))
    for i in range(n):
        np.testing.assert_allclose(
            scores[i][np.asarray(got_i[i]).astype(int)],
            np.asarray(got_s[i]),
            rtol=1e-6,
        )


def test_beam_to_mask_scatters():
    top_s = jnp.asarray([[0.5, 0.25], [0.0, 0.9]], jnp.float32)
    top_i = jnp.asarray([[3, 0], [1, 2]], jnp.int32)
    mask, ps = model.beam_to_mask(top_s, top_i, 4)
    np.testing.assert_array_equal(
        np.asarray(mask), [[1, 0, 0, 1], [0, 0, 1, 0]]
    )
    np.testing.assert_allclose(
        np.asarray(ps), [[0.25, 0, 0, 0.5], [0, 0, 0.9, 0]]
    )


def test_full_inference_agrees_with_manual_composition():
    rng = np.random.default_rng(3)
    n, d, b1, b2 = 5, 16, 4, 8
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((1, d, b1)) / 4.0, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((b1, d, b2)) / 4.0, jnp.float32)
    s, i = model.full_inference(x, w1, w2, beam=2, topk=3)
    assert s.shape == (n, 3) and i.shape == (n, 3)
    # manual: beam over layer 1, expand both beamed chunks, top-3
    s1 = jax.nn.sigmoid(x @ w1[0])  # [n, b1]
    for q in range(n):
        order = np.argsort(-np.asarray(s1[q]))
        best_parents = order[:2]
        cand = {}
        for p in best_parents:
            child_scores = jax.nn.sigmoid(x[q] @ w2[p]) * s1[q, p]
            for c in range(b2):
                cand[p * b2 + c] = float(child_scores[c])
        want = sorted(cand.values(), reverse=True)[:3]
        np.testing.assert_allclose(np.asarray(s[q]), want, rtol=1e-5)


def test_aot_export_produces_loadable_hlo(tmp_path):
    env = dict(os.environ)
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    for name in ("layer_step", "full_inference", "matmul_only"):
        path = out / f"{name}.hlo.txt"
        text = path.read_text()
        assert "HloModule" in text
        # interpret=True must have erased all Mosaic/TPU custom-calls
        assert "custom-call" not in text or "Sharding" in text, name
    assert (out / "meta.json").exists()
