"""L1 correctness: the Pallas MSCM kernel against the pure-jnp oracle.

Hypothesis sweeps shapes, mask patterns and value distributions; a
handful of deterministic edge cases pin the behaviours the rust engine
relies on (full mask, empty mask, parent-score combine).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mscm import (
    mscm_masked_matmul,
    mxu_utilization_estimate,
    vmem_bytes_per_step,
)
from compile.kernels.ref import layer_step_ref, mscm_masked_matmul_ref


def _rand_case(rng, n, d, c, b, mask_p=0.5):
    x = rng.standard_normal((n, d), dtype=np.float32)
    w = (rng.standard_normal((c, d, b)) / np.sqrt(d)).astype(np.float32)
    mask = (rng.random((n, c)) < mask_p).astype(np.float32)
    pscore = (rng.random((n, c)) * mask).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask), jnp.asarray(pscore)


shape_strategy = st.tuples(
    st.integers(1, 5),  # n
    st.sampled_from([1, 3, 8, 17]),  # d
    st.integers(1, 6),  # C
    st.sampled_from([1, 2, 5, 8]),  # B
    st.integers(0, 2**31 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_kernel_matches_reference_swept(params):
    n, d, c, b, seed = params
    rng = np.random.default_rng(seed)
    x, w, mask, pscore = _rand_case(rng, n, d, c, b)
    got = mscm_masked_matmul(x, w, mask, pscore)
    want = mscm_masked_matmul_ref(x, w, mask, pscore)
    assert got.shape == (n, c * b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_masked_blocks_are_exactly_zero(seed):
    rng = np.random.default_rng(seed)
    x, w, mask, pscore = _rand_case(rng, 3, 16, 4, 4, mask_p=0.3)
    got = np.asarray(mscm_masked_matmul(x, w, mask, pscore)).reshape(3, 4, 4)
    for i in range(3):
        for cc in range(4):
            if mask[i, cc] == 0:
                assert np.all(got[i, cc] == 0.0)


def test_full_mask_equals_dense_product():
    rng = np.random.default_rng(7)
    x, w, _, _ = _rand_case(rng, 4, 32, 3, 8)
    mask = jnp.ones((4, 3), jnp.float32)
    pscore = jnp.ones((4, 3), jnp.float32)
    got = mscm_masked_matmul(x, w, mask, pscore)
    dense = jax.nn.sigmoid(jnp.einsum("nd,cdb->ncb", x, w)).reshape(4, 24)
    np.testing.assert_allclose(got, dense, rtol=1e-5, atol=1e-6)


def test_parent_scores_scale_children():
    rng = np.random.default_rng(8)
    x, w, _, _ = _rand_case(rng, 2, 8, 2, 3)
    mask = jnp.ones((2, 2), jnp.float32)
    ones = jnp.ones((2, 2), jnp.float32)
    base = np.asarray(mscm_masked_matmul(x, w, mask, ones))
    scaled = np.asarray(mscm_masked_matmul(x, w, mask, 0.5 * ones))
    np.testing.assert_allclose(scaled, 0.5 * base, rtol=1e-6)


def test_zero_query_gives_half_sigmoid():
    w = jnp.zeros((1, 4, 2), jnp.float32)
    x = jnp.zeros((1, 4), jnp.float32)
    mask = jnp.ones((1, 1), jnp.float32)
    ps = jnp.ones((1, 1), jnp.float32)
    got = np.asarray(mscm_masked_matmul(x, w, mask, ps))
    np.testing.assert_allclose(got, 0.5 * np.ones((1, 2)), rtol=1e-6)


def test_layer_step_ref_beam_is_topk():
    rng = np.random.default_rng(9)
    x, w, mask, pscore = _rand_case(rng, 2, 8, 3, 4, mask_p=1.0)
    top_scores, top_idx = layer_step_ref(x, w, mask, pscore, beam=5)
    scores = np.asarray(mscm_masked_matmul_ref(x, w, mask, pscore))
    for i in range(2):
        want = np.sort(scores[i])[::-1][:5]
        np.testing.assert_allclose(np.asarray(top_scores[i]), want, rtol=1e-6)
        assert len(set(np.asarray(top_idx[i]).tolist())) == 5


def test_vmem_and_mxu_estimates():
    # analytic helpers used by DESIGN.md §Perf
    assert vmem_bytes_per_step(256, 32) == 4 * (256 + 256 * 32 + 32)
    assert mxu_utilization_estimate(256, 128) == 1.0
    assert mxu_utilization_estimate(256, 32) == pytest.approx(0.25)
