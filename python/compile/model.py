"""L2 — the XMR layer step as a JAX computation calling the L1 kernel.

One beam-search layer of Algorithm 1, dense-chunked for TPU:

  1. masked chunk multiplication + σ + parent combine  (L1 Pallas kernel)
  2. top-b beam selection over the child scores        (jax.lax.top_k)
  3. prolongation of the new beam to the next layer's chunk mask
     (child node → its own chunk of children; the analogue of
     ``P̃ C^T`` in Alg. 1 line 5 when chunks are contiguous)

The full tree inference is the composition of `layer_step` per layer;
`full_inference` composes a fixed two-layer tree as the end-to-end
artifact the rust runtime loads and cross-checks against its native
engine (rust/tests/runtime_artifacts.rs).

Everything here is lowered once, at build time, by aot.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.mscm import mscm_masked_matmul


def _topk(scores, k):
    """Top-k via argsort.

    ``jax.lax.top_k`` lowers to the ``topk`` HLO instruction (attribute
    ``largest``) which the bundled xla_extension 0.5.1 text parser
    rejects; ``argsort`` lowers to a plain ``sort`` with comparator,
    which round-trips fine. Ties resolve to the lower index (same as
    top_k).
    """
    idx = jnp.argsort(-scores, axis=-1, stable=True)[:, :k]
    vals = jnp.take_along_axis(scores, idx, axis=-1)
    return vals, idx


def layer_step(x, w, mask, pscore, *, beam):
    """One beam-search layer.

    Args:
      x: ``[n, d]`` dense queries.
      w: ``[C, d, B]`` chunk tiles of this layer's weights.
      mask: ``[n, C]`` active-chunk mask from the previous beam.
      pscore: ``[n, C]`` parent path scores aligned with ``mask``.
      beam: static beam width b.

    Returns:
      ``(top_scores [n, b], top_idx [n, b])`` — the new beam over this
      layer's ``C * B`` child nodes. Indices are returned as f32 (the
      rust runtime moves f32 tensors across the PJRT boundary; beam
      indices are exact below 2^24).
    """
    scores = mscm_masked_matmul(x, w, mask, pscore)
    top_scores, top_idx = _topk(scores, beam)
    return top_scores, top_idx.astype(jnp.float32)


def beam_to_mask(top_scores, top_idx, num_chunks):
    """Prolongates a beam over layer-l nodes to layer-(l+1) chunk masks.

    Child node `j` of layer l *is* parent chunk `j` of layer l+1 (chunks
    are contiguous sibling groups), so scatter the beam into dense
    ``[n, C_next]`` mask/pscore arrays.
    """
    top_idx = top_idx.astype(jnp.int32)
    n, b = top_scores.shape
    mask = jnp.zeros((n, num_chunks), jnp.float32)
    pscore = jnp.zeros((n, num_chunks), jnp.float32)
    rows = jnp.arange(n)[:, None]
    # beamed entries may include zero-score padding; keep them masked off
    valid = top_scores > 0
    mask = mask.at[rows, top_idx].max(jnp.where(valid, 1.0, 0.0))
    pscore = pscore.at[rows, top_idx].max(jnp.where(valid, top_scores, 0.0))
    return mask, pscore


def full_inference(x, w1, w2, *, beam, topk):
    """Two-layer tree inference end to end (the AOT demo artifact).

    Layer 1 has a single chunk (the root's children); its beam gates the
    chunks of layer 2. Returns ``(scores [n, topk], labels [n, topk])``.
    """
    n, _ = x.shape
    c1, _, b1 = w1.shape
    assert c1 == 1, "layer 1 is the root's single chunk"
    mask1 = jnp.ones((n, 1), jnp.float32)
    ps1 = jnp.ones((n, 1), jnp.float32)
    s1, i1 = layer_step(x, w1, mask1, ps1, beam=beam)
    c2 = w2.shape[0]
    assert c2 == b1, "one layer-2 chunk per layer-1 node"
    mask2, ps2 = beam_to_mask(s1, i1, c2)
    s2, i2 = layer_step(x, w2, mask2, ps2, beam=topk)
    return s2, i2
