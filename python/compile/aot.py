"""AOT export: lower the L2 layer step / full inference to HLO text.

HLO *text* (not ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all f32, shapes fixed at export time and recorded in
artifacts/meta.json so the rust loader can size its buffers):

  layer_step.hlo.txt      one masked-chunk-matmul + top-b beam layer
  full_inference.hlo.txt  two-layer tree end to end
  matmul_only.hlo.txt     the bare masked chunk product (kernel A/B bench)

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.mscm import mscm_masked_matmul, vmem_bytes_per_step

# Export shapes: a small but non-trivial tree — n queries, d features,
# layer-1: 1 chunk x B1 children, layer-2: B1 chunks x B2 children.
N = 8
D = 256
B1 = 16
B2 = 32
BEAM = 4
TOPK = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, args, path: str) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((N, D), f32)
    w1 = jax.ShapeDtypeStruct((1, D, B1), f32)
    w2 = jax.ShapeDtypeStruct((B1, D, B2), f32)
    mask1 = jax.ShapeDtypeStruct((N, 1), f32)
    ps1 = jax.ShapeDtypeStruct((N, 1), f32)

    export(
        functools.partial(model.layer_step, beam=BEAM),
        (x, w1, mask1, ps1),
        os.path.join(args.out_dir, "layer_step.hlo.txt"),
    )
    export(
        functools.partial(model.full_inference, beam=BEAM, topk=TOPK),
        (x, w1, w2),
        os.path.join(args.out_dir, "full_inference.hlo.txt"),
    )
    export(
        mscm_masked_matmul,
        (x, w1, mask1, ps1),
        os.path.join(args.out_dir, "matmul_only.hlo.txt"),
    )

    meta = {
        "n": N,
        "d": D,
        "b1": B1,
        "b2": B2,
        "beam": BEAM,
        "topk": TOPK,
        "dtype": "f32",
        "vmem_bytes_per_step_l2": vmem_bytes_per_step(D, B2),
        "artifacts": {
            "layer_step": {
                "inputs": [[N, D], [1, D, B1], [N, 1], [N, 1]],
                "outputs": [[N, BEAM], [N, BEAM]],
            },
            "full_inference": {
                "inputs": [[N, D], [1, D, B1], [B1, D, B2]],
                "outputs": [[N, TOPK], [N, TOPK]],
            },
            "matmul_only": {
                "inputs": [[N, D], [1, D, B1], [N, 1], [N, 1]],
                "outputs": [[N, B1]],
            },
        },
    }
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
