"""Pure-jnp oracle for the Pallas MSCM kernel.

The reference computes the masked chunk product with plain einsum — no
Pallas, no custom layout — and is the ground truth for
python/tests/test_kernel.py (hypothesis sweeps shapes against it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mscm_masked_matmul_ref(x, w, mask, pscore):
    """Reference for kernels.mscm.mscm_masked_matmul.

    Args:
      x: ``[n, d]`` dense queries.
      w: ``[C, d, B]`` chunk tiles.
      mask: ``[n, C]`` chunk activation mask.
      pscore: ``[n, C]`` parent path scores.

    Returns:
      ``[n, C * B]`` combined child scores.
    """
    n, _ = x.shape
    c, _, b = w.shape
    acts = jnp.einsum("nd,cdb->ncb", x, w)  # [n, C, B]
    scores = jax.nn.sigmoid(acts) * pscore[:, :, None]
    scores = jnp.where(mask[:, :, None] > 0, scores, 0.0)
    return scores.reshape(n, c * b)


def layer_step_ref(x, w, mask, pscore, beam):
    """Reference for model.layer_step: masked product then top-b beam."""
    scores = mscm_masked_matmul_ref(x, w, mask, pscore)
    top_scores, top_idx = jax.lax.top_k(scores, beam)
    return top_scores, top_idx
