"""L1 — the MSCM hot spot as a Pallas kernel (TPU formulation).

The paper's MSCM is a CPU sparse technique: the beam mask activates whole
sibling *chunks* of the weight matrix, and the per-chunk support
intersection is walked once per chunk. Sparse scatter/gather with
data-dependent support does not vectorize on the MXU, so the TPU
formulation (DESIGN.md §Hardware-Adaptation) keeps the paper's core
insight — *gate whole chunks with the beam mask and amortize memory
traffic per chunk* — but densifies the tiles:

- queries are dense rows ``x: [n, d]`` (one search query is short; its
  densified block is what rides in VMEM);
- weights are per-parent chunk tiles ``w: [C, d, B]`` (chunk = the B
  sibling columns under one parent — eq. 7 of the paper);
- the beam mask ``mask: [n, C]`` gates *chunks*, exactly like the block
  mask of eq. 9, and parent path-scores ``pscore: [n, C]`` implement the
  conditional-probability combine (Alg. 1 line 8).

Grid: one program per (query, chunk) — the analogue of Alg. 3's block
list. BlockSpec streams the chunk tile HBM→VMEM once per grid column, the
analogue of the paper's chunk-order evaluation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mscm_block_kernel(x_ref, w_ref, mask_ref, pscore_ref, out_ref):
    """One (query, chunk) block: out = mask ? pscore * sigmoid(x @ W_c) : 0."""
    x = x_ref[...]  # [1, d]
    w = w_ref[0]  # [d, B]
    m = mask_ref[0, 0]  # scalar
    p = pscore_ref[0, 0]  # scalar
    # MXU-shaped product: (1, d) @ (d, B) -> (1, B).
    a = jnp.dot(x, w, preferred_element_type=jnp.float32)
    act = p * jax.nn.sigmoid(a)
    out_ref[...] = jnp.where(m > 0, act, jnp.zeros_like(act))


@functools.partial(jax.jit, static_argnames=())
def mscm_masked_matmul(x, w, mask, pscore):
    """Masked chunk multiplication ``A = M ⊙ σ(X W) ⊙ P`` (eq. 6 + combine).

    Args:
      x: ``[n, d]`` dense queries.
      w: ``[C, d, B]`` chunk tiles (C chunks of B sibling columns).
      mask: ``[n, C]`` chunk activation mask (0/1 floats).
      pscore: ``[n, C]`` parent path scores.

    Returns:
      ``[n, C * B]`` combined child scores (zero where masked out).
    """
    n, d = x.shape
    c, dw, b = w.shape
    assert d == dw, f"dim mismatch {d} != {dw}"
    assert mask.shape == (n, c) and pscore.shape == (n, c)
    return pl.pallas_call(
        _mscm_block_kernel,
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d, b), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, c * b), jnp.float32),
        interpret=True,
    )(x, w, mask, pscore)


def vmem_bytes_per_step(d: int, b: int) -> int:
    """VMEM footprint of one grid step (query row + chunk tile + output).

    Used by DESIGN.md's §Perf roofline estimate: the chunk tile must fit
    comfortably in ~16 MB of VMEM with double-buffering headroom.
    """
    return 4 * (d + d * b + b)


def mxu_utilization_estimate(d: int, b: int) -> float:
    """Fraction of an (128x128)-MXU pass doing useful work for one block.

    The (1, d) x (d, B) product tiles the MXU as ceil(d/128) passes of
    width ceil(B/128)*128; utilization is B / (ceil(B/128)*128) times the
    1/8 row occupancy of a single-query pass (batching queries to 8 rows
    restores it — documented trade-off).
    """
    lanes = -(-b // 128) * 128
    return min(1.0, b / lanes)
